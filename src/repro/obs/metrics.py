"""Metrics registry: named counters, gauges, and fixed-bucket histograms.

One process-wide :class:`MetricsRegistry` gives every number in the
system a single canonical name, one snapshot call, and one reset path —
the engines' stats dataclasses and the kernel meter publish into it
through the thin adapters in :mod:`repro.obs.adapters`.

Naming scheme (DESIGN.md §Observability): dotted lowercase paths,
``<subsystem>.<metric>`` — e.g. ``cmat.rounds``,
``dist.exchanges_skipped``, ``kernels.member.calls``,
``storage.checkpoints``.  The prefix is the reset scope:
``registry.reset("kernels.")`` zeroes the kernel meter without touching
anything else (the per-suite isolation ``benchmarks/run.py`` relies on).

* **Counter** — monotonic within a scope; ``inc(n)``.
* **Gauge** — last-write-wins level; ``set(v)``.
* **Histogram** — fixed log-spaced buckets; ``observe(v)`` is one
  ``bisect`` + two adds, quantiles (p50/p95/p99) are interpolated from
  the bucket counts at snapshot time, exact to bucket resolution
  (~±12% with the default 10-buckets-per-decade bounds; the min/max
  tracks tighten the edge buckets).

Snapshots are *flat dicts of scalars* — the same shape the bench
artifact schema enforces — with histograms expanded to
``name.count`` / ``name.sum`` / ``name.p50`` / ``name.p95`` /
``name.p99`` / ``name.max``.
"""

from __future__ import annotations

from bisect import bisect_left

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "default_buckets",
]


def default_buckets() -> list[float]:
    """Log-spaced bucket upper bounds, 10 per decade over 1e-7..1e4 —
    wide enough for latencies in seconds and row/byte counts alike."""
    return [10.0 ** (-7 + i / 10.0) for i in range(111)]


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed-bucket histogram; bucket ``i`` counts observations ``v``
    with ``bounds[i-1] < v <= bounds[i]`` (bucket 0: ``v <= bounds[0]``,
    the last bucket: ``v > bounds[-1]``)."""

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, bounds: list[float] | None = None):
        self.bounds = list(bounds) if bounds is not None else default_buckets()
        if sorted(self.bounds) != self.bounds:
            raise ValueError("histogram bounds must be sorted ascending")
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def quantile(self, q: float) -> float:
        """Interpolated quantile from the bucket counts (0 with no
        observations).  Matches ``numpy.percentile`` to within one
        bucket's width."""
        if self.count == 0:
            return 0.0
        target = q * (self.count - 1) + 1  # 1-based rank, linear method
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                # interpolate inside bucket i; clamp the open edges with
                # the observed min/max so single-bucket histograms and
                # the overflow bucket stay finite
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.max


class MetricsRegistry:
    """Get-or-create registry of named metrics (see module docstring)."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    # ------------------------------------------------------------------ #
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._check_fresh(name)
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._check_fresh(name)
            g = self._gauges[name] = Gauge()
        return g

    def histogram(
        self, name: str, bounds: list[float] | None = None
    ) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            self._check_fresh(name)
            h = self._hists[name] = Histogram(bounds)
        return h

    def _check_fresh(self, name: str) -> None:
        if (
            name in self._counters
            or name in self._gauges
            or name in self._hists
        ):
            raise ValueError(
                f"metric {name!r} already registered with a different type"
            )

    # ------------------------------------------------------------------ #
    def snapshot(self, prefix: str = "") -> dict[str, float | int]:
        """Flat ``{name: scalar}`` view of every metric under ``prefix``
        (histograms expand to count/sum/p50/p95/p99/max)."""
        out: dict[str, float | int] = {}
        for name, c in self._counters.items():
            if name.startswith(prefix):
                out[name] = c.value
        for name, g in self._gauges.items():
            if name.startswith(prefix):
                out[name] = g.value
        for name, h in self._hists.items():
            if not name.startswith(prefix):
                continue
            out[f"{name}.count"] = h.count
            out[f"{name}.sum"] = h.sum
            out[f"{name}.p50"] = h.quantile(0.50)
            out[f"{name}.p95"] = h.quantile(0.95)
            out[f"{name}.p99"] = h.quantile(0.99)
            out[f"{name}.max"] = h.max if h.count else 0.0
        return out

    def reset(self, prefix: str = "") -> None:
        """Zero every metric under ``prefix`` (all of them by default).
        Metrics stay registered — adapters and report renderers keep
        their handles."""
        for name, c in self._counters.items():
            if name.startswith(prefix):
                c.reset()
        for name, g in self._gauges.items():
            if name.startswith(prefix):
                g.reset()
        for name, h in self._hists.items():
            if name.startswith(prefix):
                h.reset()


#: the process-wide registry every adapter publishes into
_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide registry (returns the previous one)."""
    global _REGISTRY
    prev = _REGISTRY
    _REGISTRY = registry
    return prev
