"""Span tracer: nested, low-overhead, host-side only.

One process-wide :class:`Tracer` (swap it with :func:`set_tracer`)
records *complete* spans — name, start, duration, nesting depth, and a
flat attribute dict — with ``time.perf_counter_ns`` timestamps.  Spans
are context managers::

    from repro.obs import span

    with span("cmat.round", round=3, stratum=0):
        ...work...

Design constraints (DESIGN.md §Observability):

* **Disabled is free.**  The default tracer is disabled;
  ``tracer.span(...)`` then returns a shared no-op singleton — no event
  allocation, no timestamp read, no stack push.  Engines can leave
  their instrumentation unguarded in host-side loops.
* **Host boundaries only.**  Spans read the wall clock and append to a
  Python list; they must never execute inside traced/jitted code, where
  the side effect would fire once per trace instead of per execution
  (the same rule the kernel meter and ``DistributedStats`` follow).
  Instrument where the engines already count rounds.
* **Bounded.**  At ``max_events`` the tracer stops recording (and
  counts the drops) instead of growing without bound under a serving
  loop left tracing for hours.

The recorded span list converts losslessly to the Chrome trace-event /
Perfetto JSON format (:mod:`repro.obs.export`) — open the file in
``chrome://tracing`` or https://ui.perfetto.dev.
"""

from __future__ import annotations

import threading
import time

__all__ = [
    "Tracer",
    "SpanRecord",
    "get_tracer",
    "set_tracer",
    "span",
    "instant",
]


class SpanRecord:
    """One closed span: ``name``, ``start_ns``/``dur_ns`` (perf-counter
    clock), ``depth`` (0 = root), ``tid``, and ``args``."""

    __slots__ = ("name", "start_ns", "dur_ns", "depth", "tid", "args")

    def __init__(self, name, start_ns, dur_ns, depth, tid, args):
        self.name = name
        self.start_ns = start_ns
        self.dur_ns = dur_ns
        self.depth = depth
        self.tid = tid
        self.args = args

    def __repr__(self):  # pragma: no cover - debugging aid
        return (
            f"SpanRecord({self.name!r}, dur={self.dur_ns / 1e6:.3f}ms, "
            f"depth={self.depth}, args={self.args!r})"
        )


class _NoopSpan:
    """Shared do-nothing context manager for the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        """No-op twin of :meth:`_Span.set`."""
        return self


_NOOP = _NoopSpan()


class _Span:
    """Live span handle; records itself into the tracer on ``__exit__``.

    The record is appended at *exit* (Chrome 'X' complete events carry
    start + duration), so children appear before their parent in the
    event list; ordering by ``start_ns`` recovers program order and the
    exporter does not care.
    """

    __slots__ = ("_tracer", "name", "args", "_start", "_depth")

    def __init__(self, tracer: Tracer, name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def set(self, **kw):
        """Attach attributes discovered mid-span (e.g. cache hit/miss)."""
        self.args.update(kw)
        return self

    def __enter__(self):
        stack = self._tracer._stack()
        self._depth = len(stack)
        stack.append(self)
        self._start = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._start
        tracer = self._tracer
        stack = tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        else:  # mis-nested exit: recover rather than corrupt the stack
            try:
                stack.remove(self)
            except ValueError:
                pass
            tracer.misnested += 1
        tracer._record(
            SpanRecord(
                self.name,
                self._start,
                dur,
                self._depth,
                threading.get_ident(),
                self.args,
            )
        )
        return False


class Tracer:
    """Process-wide span recorder (see module docstring)."""

    def __init__(self, enabled: bool = False, max_events: int = 1_000_000):
        self.enabled = bool(enabled)
        self.max_events = int(max_events)
        self.events: list[SpanRecord] = []
        #: spans/instants not recorded because ``max_events`` was hit
        self.dropped = 0
        #: spans exited out of LIFO order (a bug in instrumentation)
        self.misnested = 0
        #: observers called as ``hook(tracer, record)`` on every span
        #: close / instant — the attachment point for samplers that need
        #: span *boundaries* (e.g. memory watermarks) without touching
        #: the instrumentation sites.  Hooks run on the recording thread
        #: and must be cheap; exceptions are swallowed and counted so a
        #: broken observer can never take an engine down.
        self.hooks: list = []
        self.hook_errors = 0
        self._local = threading.local()
        #: perf-counter origin for relative timestamps in exports
        self.origin_ns = time.perf_counter_ns()
        #: wall-clock at origin (Perfetto UIs show absolute times)
        self.origin_unix_s = time.time()

    # ------------------------------------------------------------------ #
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, rec: SpanRecord) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
        else:
            self.events.append(rec)
        # hooks still see boundaries once the event buffer is full —
        # watermark samplers must not stop with the recording.
        if self.hooks:
            for hook in tuple(self.hooks):
                try:
                    hook(self, rec)
                except Exception:
                    self.hook_errors += 1

    def add_hook(self, hook) -> None:
        """Register a ``hook(tracer, record)`` span-boundary observer."""
        if hook not in self.hooks:
            self.hooks.append(hook)

    def remove_hook(self, hook) -> None:
        if hook in self.hooks:
            self.hooks.remove(hook)

    # ------------------------------------------------------------------ #
    def span(self, name: str, **args):
        """Context manager timing one named span.  Disabled tracers
        return a shared no-op singleton (the zero-cost fast path)."""
        if not self.enabled:
            return _NOOP
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker event (regrows, WAL appends, ...).
        Recorded with ``dur_ns == -1`` so the exporter can tell a marker
        from a genuinely sub-resolution span."""
        if not self.enabled:
            return
        self._record(
            SpanRecord(
                name,
                time.perf_counter_ns(),
                -1,
                len(self._stack()),
                threading.get_ident(),
                args,
            )
        )

    # ------------------------------------------------------------------ #
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop recorded events (the enabled flag is unchanged)."""
        self.events = []
        self.dropped = 0
        self.misnested = 0
        self.origin_ns = time.perf_counter_ns()
        self.origin_unix_s = time.time()

    def sorted_events(self) -> list[SpanRecord]:
        """Events in program (start-time) order — exits append children
        before parents, so the raw list is end-time ordered."""
        return sorted(self.events, key=lambda r: (r.start_ns, -r.dur_ns))


#: the process-wide tracer every ``repro.obs.span(...)`` call hits
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-wide tracer (returns the previous one)."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


def span(name: str, **args):
    """Span on the process-wide tracer (the call every instrumentation
    site uses — re-reads the global, so enabling mid-process works)."""
    return _TRACER.span(name, **args)


def instant(name: str, **args) -> None:
    """Instant event on the process-wide tracer."""
    _TRACER.instant(name, **args)
