"""Batched serving driver: prefill + decode with a KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen-len 32

Implements the standard serving loop: a batch of requests is prefilled
token-by-token into the cache (teacher-forced), then decoded greedily.
On a pod the same step functions run under the production mesh with the
cache shardings of ``launch.sharding``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import set_mesh
from ..configs import get_config
from ..models.model import Model
from .mesh import make_host_mesh
from ..models.sharding_policy import set_policy_from_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(1, 1)
    set_policy_from_mesh(mesh)
    model = Model(cfg)

    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        max_len = args.prompt_len + args.gen_len
        cache = model.init_cache(args.batch, max_len)
        step = jax.jit(model.decode_step)

        prompts = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.batch, args.prompt_len),
            0,
            cfg.vocab_size,
            jnp.int32,
        )

        # prefill: feed prompt tokens through the decode path
        t0 = time.time()
        logits = None
        for t in range(args.prompt_len):
            logits, cache = step(params, prompts[:, t : t + 1], cache,
                                 jnp.int32(t))
        t_prefill = time.time() - t0

        # greedy decode
        t0 = time.time()
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated = [token]
        for t in range(args.prompt_len, max_len - 1):
            logits, cache = step(params, token, cache, jnp.int32(t))
            token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            generated.append(token)
        t_decode = time.time() - t0

    out = np.concatenate([np.asarray(t) for t in generated], axis=1)
    n_tok = out.shape[0] * out.shape[1]
    print(f"prefill: {args.prompt_len} steps in {t_prefill:.2f}s")
    print(
        f"decode:  {out.shape[1]} steps x batch {args.batch} = {n_tok} tokens "
        f"in {t_decode:.2f}s ({n_tok / max(t_decode, 1e-9):.1f} tok/s)"
    )
    print("sample token ids:", out[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
