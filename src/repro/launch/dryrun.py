"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Run as ``PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
[--mesh single|multi|both] [--out DIR]``.

The placeholder-device override MUST precede every other import (jax locks
the device count at first init).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..compat import set_mesh  # noqa: E402
from ..configs import SHAPES, get_config, list_configs  # noqa: E402
from ..models.model import abstract_params, input_specs  # noqa: E402
from ..models import transformer  # noqa: E402
from ..optim import adamw_init  # noqa: E402
from ..train import TrainConfig, make_serve_step, make_train_step  # noqa: E402
from ..train import make_prefill_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .sharding import (  # noqa: E402
    batch_shardings,
    cache_shardings,
    param_shardings,
    state_shardings,
)

#: cells skipped per DESIGN.md §Arch-applicability: long_500k requires a
#: sub-quadratic architecture (SSM / hybrid).
def cell_skipped(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "long_500k skipped: pure full-attention arch (DESIGN.md)"
    return None




def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               sequence_parallel: bool = False, microbatches: int = 1,
               remat: str = "full", strategy: str = "fsdp_tp"):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_skipped(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "SKIP", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    from ..models.sharding_policy import set_policy_from_mesh

    set_policy_from_mesh(mesh, sequence_parallel=sequence_parallel,
                         strategy=strategy)
    transformer.set_remat_policy(remat)

    def p_shardings(tree):
        return param_shardings(tree, mesh, strategy=strategy)

    t0 = time.time()
    with set_mesh(mesh):
        params_abs = abstract_params(cfg)
        if shape.kind == "train":
            state_abs = {
                "params": params_abs,
                "opt": jax.eval_shape(adamw_init, params_abs),
            }
            in_batch = input_specs(cfg, shape)
            from jax.sharding import NamedSharding, PartitionSpec as P

            state_sh = {
                "params": p_shardings(state_abs["params"]),
                "opt": {
                    "mu": p_shardings(state_abs["opt"]["mu"]),
                    "nu": p_shardings(state_abs["opt"]["nu"]),
                    "step": NamedSharding(mesh, P()),
                },
            }
            batch_sh = batch_shardings(in_batch, mesh)
            step = make_train_step(cfg, TrainConfig(microbatches=microbatches))
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh))
            lowered = jitted.lower(state_abs, in_batch)
        elif shape.kind == "prefill":
            in_batch = input_specs(cfg, shape)
            p_sh = p_shardings(params_abs)
            b_sh = batch_shardings(in_batch, mesh)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_abs, in_batch)
        else:  # decode
            specs = input_specs(cfg, shape)
            p_sh = p_shardings(params_abs)
            cache_sh = cache_shardings(specs["cache"], mesh, shape.global_batch)
            tok_sh = batch_shardings(
                {"token": specs["token"]}, mesh
            )["token"]
            from jax.sharding import NamedSharding, PartitionSpec as P

            len_sh = NamedSharding(mesh, P())
            step = make_serve_step(cfg)
            if cfg.family == "encdec":
                mem_sh = batch_shardings({"m": specs["memory"]}, mesh)["m"]
                jitted = jax.jit(
                    step, in_shardings=(p_sh, tok_sh, cache_sh, len_sh, mem_sh)
                )
                lowered = jitted.lower(
                    params_abs, specs["token"], specs["cache"],
                    specs["cache_len"], specs["memory"],
                )
            else:
                jitted = jax.jit(
                    step, in_shardings=(p_sh, tok_sh, cache_sh, len_sh)
                )
                lowered = jitted.lower(
                    params_abs, specs["token"], specs["cache"],
                    specs["cache_len"],
                )

        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # loop-trip-count-corrected per-device costs from the partitioned
        # HLO (cost_analysis counts while bodies once — see roofline/)
        from ..roofline.hlo_cost import analyze_hlo

        hlo_cost = analyze_hlo(compiled.as_text())

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "OK",
        "n_devices": int(mesh.devices.size),
        "compile_s": round(t_compile, 1),
        # raw XLA numbers (per-device, while-bodies counted once):
        "xla_flops_body_once": float(cost.get("flops", -1.0)) if cost else -1.0,
        "xla_bytes_body_once": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        # loop-corrected per-device numbers (roofline inputs):
        "flops_per_device": hlo_cost.flops,
        "hbm_bytes_per_device": hlo_cost.bytes_written,
        "collective_bytes_per_device": dict(hlo_cost.collective_bytes),
        "collective_total_per_device": hlo_cost.total_collective_bytes,
        "loop_trip_counts": hlo_cost.trip_counts,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None
            ),
        },
    }
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--sp", action="store_true", help="sequence parallelism")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    ap.add_argument("--strategy", default="fsdp_tp",
                    choices=["fsdp_tp", "pure_fsdp", "fsdp_ep"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape}_{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    print(f"[cached] {tag}: {rec['status']}")
                    results.append(rec)
                    continue
                try:
                    rec = lower_cell(arch, shape, multi,
                                     sequence_parallel=args.sp,
                                     microbatches=args.microbatches,
                                     remat=args.remat,
                                     strategy=args.strategy)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "multi" if multi else "single",
                        "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                extra = (
                    f"compile={rec.get('compile_s')}s"
                    if status == "OK"
                    else rec.get("reason", rec.get("error", ""))[:100]
                )
                print(f"[{status}] {tag}: {extra}", flush=True)
                results.append(rec)

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\ndry-run complete: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
