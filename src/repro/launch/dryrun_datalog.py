"""Dry-run the distributed datalog round on production-scale meshes.

    PYTHONPATH=src python -m repro.launch.dryrun_datalog

Lowers one semi-naive round of the hash-partitioned engine (the paper's
materialisation as a cluster workload) at 256 and 512 shards, proving the
all_to_all exchange + join schedule partitions coherently, and records
the roofline terms of a reasoning round.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import json  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from ..core.distributed import DistributedEngine  # noqa: E402
from ..core.generators import lubm_like  # noqa: E402
from ..roofline.hlo_cost import analyze_hlo  # noqa: E402


def lower_round(n_shards: int, capacity: int = 1 << 12):
    mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("data",))
    program, dataset, _ = lubm_like(n_dept=8, n_students=200, n_courses=32)
    rules = [r for r in program if len(r.body) <= 2]
    program = type(program)(rules)

    eng = DistributedEngine(program, mesh, capacity=capacity)
    preds = tuple(sorted(set(dataset) | program.predicates()))
    arities = {}
    for p in preds:
        if p in dataset:
            r = np.asarray(dataset[p])
            arities[p] = 1 if r.ndim == 1 else r.shape[1]
    for rule in program:
        for atom in (rule.head, *rule.body):
            arities.setdefault(atom.predicate, atom.arity)

    round_fn, abstract = eng.abstract_round(preds, arities)

    t0 = time.time()
    lowered = round_fn.lower(*abstract)
    compiled = lowered.compile()
    cost = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "n_shards": n_shards,
        "capacity": capacity,
        "n_rules": len(program.rules),
        "compile_s": round(time.time() - t0, 1),
        "flops_per_device": cost.flops,
        "hbm_bytes_per_device": cost.bytes_written,
        "collective_bytes_per_device": dict(cost.collective_bytes),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
    }


def main():
    out_dir = "experiments/dryrun_datalog"
    os.makedirs(out_dir, exist_ok=True)
    for shards in (256, 512):
        rec = lower_round(shards)
        path = os.path.join(out_dir, f"round_{shards}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        colls = rec["collective_bytes_per_device"]
        print(
            f"[OK] datalog round @ {shards} shards: compile {rec['compile_s']}s, "
            f"collective/dev {sum(colls.values()):.2e} B "
            f"({', '.join(f'{k}={v:.1e}' for k, v in colls.items())})"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
