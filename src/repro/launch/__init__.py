"""Launch layer: meshes, sharding rules, dry-run, train/serve drivers."""

from .mesh import data_axes, make_host_mesh, make_production_mesh
from .sharding import (
    batch_shardings,
    cache_shardings,
    guarded_spec,
    param_shardings,
    state_shardings,
)

__all__ = [
    "batch_shardings",
    "cache_shardings",
    "data_axes",
    "guarded_spec",
    "make_host_mesh",
    "make_production_mesh",
    "param_shardings",
    "state_shardings",
]
