"""Sharding rules: parameters, optimizer states, batches, caches.

Layout strategy (see DESIGN.md §6):

* **FSDP x TP**: every weight is sharded over the batch axes
  (('pod','data')) on its d_model-ish dimension *and* over ``model`` on
  its heads/ffn/expert dimension.  Under scan-over-layers XLA all-gathers
  one layer's weights per scan step (FSDP), overlapping with compute.
* **EP**: MoE expert dim shards over ``model``.
* **Context parallelism**: decode caches with batch < data-axis size
  (long_500k) shard the *sequence* dimension of the KV cache / the state
  dimension of SSM states over ``data`` instead.
* Every rule is divisibility-guarded: a dimension that does not divide by
  the axis size is replicated instead (e.g. granite's kv=1 MQA heads fall
  back to sharding head_dim).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .mesh import data_axes

__all__ = [
    "guarded_spec",
    "param_shardings",
    "state_shardings",
    "batch_shardings",
    "cache_shardings",
]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def guarded_spec(mesh: Mesh, shape, proposed) -> P:
    """Drop proposed axes that do not divide the dimension size."""
    out = []
    for dim, axis in zip(shape, proposed):
        if axis is not None and dim % _axis_size(mesh, axis) == 0 and dim > 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# --------------------------------------------------------------------- #
# parameter rules
# --------------------------------------------------------------------- #
def _param_rule(path: str, shape, mesh: Mesh, fsdp, ep_only: bool = False) -> P:
    """Sharding for one parameter leaf, dispatched on name + rank.

    Stage parameters carry a leading layer axis (never sharded); the rules
    below give the spec for the *trailing* dims and are left-padded.
    ``ep_only``: keep the model axis for MoE experts only; everything else
    is FSDP-sharded with no tensor parallelism (best for MoE models whose
    d_model is too small to amortise TP all-reduces — §Perf iteration 8).
    """
    name = path.split("/")[-1]
    is_moe = "/moe/" in path and "shared" not in path

    def pad(spec_tail):
        return (None,) * (len(shape) - len(spec_tail)) + tuple(spec_tail)

    if name in ("embed",):
        # vocab over `model` so logits stay (b@dp, s, V@model) and the
        # softmax/xent reduce is a small all-reduce over `model`.  d is
        # deliberately NOT sharded: a d@data embed table propagates
        # feature-sharding into the activations and kills data
        # parallelism (observed; see EXPERIMENTS.md §Perf iteration 0).
        tail = ("model", None)
    elif name == "unembed":
        tail = (None, "model")
    elif name == "router":
        tail = (fsdp, None)
    elif name in ("wq",):
        tail = (fsdp, "model", None)
    elif name in ("wk", "wv"):
        # kv heads may be too few to shard (MQA) — guard falls back; try
        # sharding head_dim instead when kv-dim sharding is impossible.
        kv = shape[-2]
        if kv % _axis_size(mesh, "model") == 0:
            tail = (fsdp, "model", None)
        else:
            tail = (fsdp, None, "model")
    elif name == "wo":
        tail = ("model", None, fsdp)
    elif name in ("w_gate", "w_up"):
        tail = ("model", fsdp, None) if is_moe else (fsdp, "model")
    elif name == "w_down":
        tail = ("model", None, fsdp) if is_moe else ("model", fsdp)
    elif name == "wq_a" or name == "wkv_a":
        tail = (fsdp, None)
    elif name in ("wq_b", "wk_b", "wv_b"):
        tail = (None, "model", None)
    elif name == "in_proj":
        tail = (fsdp, "model")
    elif name == "out_proj":
        tail = ("model", fsdp)
    elif name == "conv_w":
        tail = (None, "model")
    elif name in ("conv_b", "dt_bias", "D"):
        tail = ("model",)
    elif name == "x_proj":
        tail = ("model", None)
    elif name == "dt_proj":
        tail = (None, "model")
    elif name == "A_log":
        # mamba1: (..., d_in, state) — shard d_in;  mamba2: (..., nh) —
        # shard the head dim.  d_in is always >= 512 in real configs.
        if len(shape) >= 2 and shape[-2] >= 512:
            tail = ("model", None)
        else:
            tail = ("model",)
    else:  # norms, scales, small vectors -> replicated
        return P(*([None] * len(shape)))

    if ep_only and not is_moe:
        # strip tensor parallelism: any 'model' entry becomes replicated
        tail = tuple(None if a == "model" else a for a in tail)
    spec = pad(tail)
    return guarded_spec(mesh, shape, spec)


def param_shardings(abstract_params, mesh: Mesh, strategy: str = "fsdp_tp"):
    """NamedSharding tree for a parameter pytree (abstract or concrete).

    ``strategy='fsdp_tp'`` (default): weights sharded FSDP over the batch
    axes x TP over ``model``.  ``strategy='pure_fsdp'``: no tensor
    parallelism — weights fully sharded over *every* mesh axis and
    activations batch-sharded over every axis; optimal for models whose
    per-shard TP matmuls would be tiny relative to the TP all-reduces
    (see EXPERIMENTS.md §Perf, llama3.2-1b iteration).
    """
    if strategy == "pure_fsdp":
        all_axes = tuple(mesh.axis_names)
        fsdp = all_axes if len(all_axes) > 1 else all_axes[0]
        n = _axis_size(mesh, fsdp)

        def one(path_parts, leaf):
            # shard the largest dimension divisible by the full device
            # count; small tensors (norm scales, biases) stay replicated
            spec = [None] * len(leaf.shape)
            for i, d in sorted(enumerate(leaf.shape), key=lambda t: -t[1]):
                if d > 0 and d % n == 0:
                    spec[i] = fsdp
                    break
            return NamedSharding(mesh, P(*spec))

        return _tree_map_with_path(one, abstract_params)

    fsdp = data_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]
    ep_only = strategy == "fsdp_ep"

    def one(path_parts, leaf):
        path = "/".join(str(p) for p in path_parts)
        spec = _param_rule(path, leaf.shape, mesh, fsdp, ep_only=ep_only)
        return NamedSharding(mesh, spec)

    return _tree_map_with_path(one, abstract_params)


def _tree_map_with_path(fn, tree):
    def convert(kp, leaf):
        parts = []
        for entry in kp:
            if hasattr(entry, "key"):
                parts.append(entry.key)
            elif hasattr(entry, "idx"):
                parts.append(str(entry.idx))
            else:
                parts.append(str(entry))
        return fn(parts, leaf)

    return jax.tree_util.tree_map_with_path(convert, tree)


def state_shardings(abstract_state, mesh: Mesh):
    """Train state: params + AdamW moments inherit the param layout
    (ZeRO); scalars replicated."""
    p_shard = param_shardings(abstract_state["params"], mesh)
    out = {"params": p_shard}
    if "opt" in abstract_state:
        out["opt"] = {
            "mu": param_shardings(abstract_state["opt"]["mu"], mesh),
            "nu": param_shardings(abstract_state["opt"]["nu"], mesh),
            "step": NamedSharding(mesh, P()),
        }
    if "error_feedback" in abstract_state:
        out["error_feedback"] = param_shardings(
            abstract_state["error_feedback"], mesh
        )
    return out


# --------------------------------------------------------------------- #
# batch / cache rules
# --------------------------------------------------------------------- #
def batch_shardings(abstract_batch, mesh: Mesh):
    """Training / prefill batches: leading batch dim over the DP axes."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def one(leaf):
        spec = guarded_spec(
            mesh, leaf.shape, (dp,) + (None,) * (len(leaf.shape) - 1)
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, abstract_batch)


def cache_shardings(abstract_cache_tree, mesh: Mesh, batch_size: int):
    """Decode caches.

    Layout per leaf (layer-stacked): (L, b, S, heads, hd) for KV caches,
    (L, b, ...) for SSM states.  If the batch divides the DP axes, shard
    batch; otherwise (long-context, batch=1) shard the sequence axis of KV
    caches / the widest state axis of SSM states over ``data``
    (context parallelism).
    """
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    dp_size = _axis_size(mesh, dp)
    batch_fits = batch_size % dp_size == 0 and batch_size >= dp_size

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 2:
            if batch_fits:
                spec[1] = dp
            elif len(shape) >= 3:
                # context parallel: shard the largest non-batch axis
                spec[2] = "data"
            # shard heads/feature dim over model where possible
            if len(shape) >= 4:
                spec[3] = "model"
            elif len(shape) == 3 and not batch_fits:
                pass
        return NamedSharding(mesh, guarded_spec(mesh, shape, spec))

    return jax.tree_util.tree_map(one, abstract_cache_tree)
