"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading ``pod``
axis crosses the data-center interconnect, so only data-parallel traffic
(gradient all-reduce, optionally int8-compressed) lands on it.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh", "data_axes", "DP_AXES"]

DP_AXES = ("pod", "data")  # gradient/batch axes when multi-pod


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()[:n]
    return Mesh(np.asarray(devices).reshape(data, model), ("data", "model"))


def data_axes(mesh: Mesh):
    """The batch/FSDP axes present in a mesh (('pod','data') or ('data',))."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
