"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires every substrate together: config -> model -> data pipeline (synthetic
or KB-linearised) -> sharded train step -> checkpointing -> fault-tolerant
supervision loop.  On this CPU container it trains reduced configs; on a
pod the same driver runs the full configs (the mesh adapts to the device
count).
"""

from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import set_mesh
from ..configs import get_config
from ..data import DataConfig, SyntheticCorpus, TokenStream, linearise_materialisation
from ..optim import AdamWConfig
from ..train import (
    AsyncCheckpointer,
    TrainConfig,
    init_train_state,
    latest_step,
    load_checkpoint,
    make_train_step,
)
from .mesh import make_host_mesh
from ..models.sharding_policy import set_policy_from_mesh


def build_kb_stream(cfg, data_cfg: DataConfig):
    """Materialise a synthetic KB with the CompMat engine and linearise it
    into the training stream (the paper's engine as the data substrate)."""
    from ..core import CMatEngine
    from ..core.generators import lubm_like

    program, dataset, _ = lubm_like(n_dept=20, n_students=400, n_courses=40)
    engine = CMatEngine(program)
    engine.load(dataset)
    engine.materialise()
    tokens = linearise_materialisation(engine, cfg.vocab_size)
    return TokenStream(tokens, data_cfg)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--kb-corpus", action="store_true",
                    help="train on the CompMat-materialised KB stream")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(1, 1)
    set_policy_from_mesh(mesh)

    train_cfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
    )
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        global_batch=args.batch,
        seed=args.seed,
    )
    corpus = (
        build_kb_stream(cfg, data_cfg)
        if args.kb_corpus
        else SyntheticCorpus(data_cfg)
    )

    with set_mesh(mesh):
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, train_cfg)
        step_fn = jax.jit(make_train_step(cfg, train_cfg))

        start = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = AsyncCheckpointer(args.ckpt_dir)
            if latest_step(args.ckpt_dir) is not None:
                state, start = load_checkpoint(args.ckpt_dir, state)
                start += 1
                print(f"restored checkpoint, resuming at step {start}")

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {
                k: jnp.asarray(v) for k, v in corpus.batch(step).items()
            }
            if cfg.family == "vlm":
                batch["vision_embeds"] = jnp.zeros(
                    (args.batch, 16, cfg.d_model), jnp.bfloat16
                )
            if cfg.family == "encdec":
                batch["src_embeds"] = jnp.zeros(
                    (args.batch, 2 * args.seq, cfg.d_model), jnp.bfloat16
                )
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0:
                dt = time.time() - t0
                print(
                    f"step {step:5d}  loss {losses[-1]:8.4f}  "
                    f"gnorm {float(metrics['grad_norm']):7.3f}  "
                    f"({dt:.1f}s)", flush=True,
                )
            if ckpt and step % args.ckpt_every == 0 and step > start:
                ckpt.save(step, state)
        if ckpt:
            ckpt.wait()
            ckpt.save(args.steps - 1, state)
            ckpt.wait()

    first = np.mean(losses[: max(len(losses) // 10, 1)])
    last = np.mean(losses[-max(len(losses) // 10, 1):])
    print(f"\ndone: loss {first:.4f} -> {last:.4f} over {len(losses)} steps")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
