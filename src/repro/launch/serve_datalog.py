"""Batched datalog query serving: materialise once, answer a query stream.

    PYTHONPATH=src python -m repro.launch.serve_datalog --kb lubm \
        --n-queries 2000 --zipf 1.1

The request path the paper's preprocessing framing implies: load a KB,
run the compressed materialisation once, freeze the store, then serve a
stream of templated BGP queries through :class:`repro.query.QueryEngine`
(LRU plan + result caches, scratch-region reclamation per miss) and
report p50/p99 latency, throughput, cache hit rate, and the compressed
answering evidence (flat rows scanned vs stored rows per predicate).

Query streams are drawn from per-KB templates with Zipf-distributed
constants — a serving-realistic skew where popular entities repeat and
the result cache pays off.  ``--no-result-cache`` measures pure
evaluation throughput instead.

``--live`` turns the driver into an *update-serving* loop: the KB is
held in an :class:`repro.incremental.IncrementalStore`, and every
``--update-every`` queries a batch of ``--update-size`` explicit facts
is deleted (and the batch deleted one update earlier re-inserted, so the
KB churns without draining).  Each applied batch bumps the query
engine's epoch, invalidating the version-stamped plan/result caches;
the report adds apply-latency percentiles, per-epoch stale evictions,
and — with ``--live-verify`` — a final differential check against a
from-scratch materialisation of the ending fact set.

``--distributed`` runs the sharded engine alongside the host store: the
KB is hash-partitioned over every visible device, materialised with the
semi-naive delta exchange, and — under ``--live`` — every update batch
is *also* routed through ``DistributedEngine.apply`` (overdelete /
rederive / insert deltas through ``all_to_all``), with a final
differential ``check_integrity`` against the host
:class:`~repro.incremental.IncrementalStore` serving the queries.

``--checkpoint-dir`` makes the store durable (DESIGN.md §Storage):
update batches are write-ahead logged, a snapshot is checkpointed every
``--checkpoint-every`` batches, and ``--restore`` warm-starts from the
latest snapshot + WAL replay instead of re-materialising (recovery
timing lands in the report).  In live mode ``--compact-threshold``
triggers a GC/compaction epoch whenever deletion churn strands more
than that fraction of mu-nodes.  Without ``--live``, the checkpoint dir
holds a single frozen snapshot of the static materialisation and
``--restore`` serves straight from it.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

from ..core import CMatEngine
from ..core.generators import chain, lubm_like, paper_example, star
from ..incremental import IncrementalStore
from ..obs import (
    get_registry,
    get_tracer,
    publish_predicate_effectiveness,
    publish_query_cache,
    publish_serving,
    sample_memory,
    span,
    write_chrome_trace,
    write_metrics,
)
from ..query import QueryEngine
from ..storage import CheckpointManager, load_frozen, write_snapshot


class ReportSink:
    """Report sink: every block prints its legacy ``[tag] ...`` line and
    (with ``--report-json``) appends one JSON object per block —
    ``{"block": tag, ...data}`` — so drivers can scrape structure
    instead of parsing the text.

    Thread-safe: concurrent serving emits from client/executor threads,
    so the print and the JSON append happen under one lock (interleaved
    ``[tag]`` lines and torn JSON records otherwise).  Each record is
    serialised *outside* the lock and written with a single ``write``."""

    def __init__(self, json_path: str | None = None):
        self._fh = open(json_path, "w") if json_path else None
        self._lock = threading.Lock()

    def emit(self, block: str, text: str, data: dict | None = None) -> None:
        line = f"[{block}] {text}"
        rec = None
        if self._fh is not None:
            payload = {"block": block}
            payload.update(data or {})
            rec = json.dumps(payload, default=float, sort_keys=True) + "\n"
        with self._lock:
            print(line)
            if rec is not None and self._fh is not None:
                self._fh.write(rec)
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


#: historical name, kept for callers that imported the class directly
Report = ReportSink


def build_kb(name: str, scale: int):
    if name == "lubm":
        return lubm_like(
            n_dept=4 * scale, n_students=100 * scale, n_courses=8 * scale, seed=0
        )
    if name == "chain":
        return chain(n=60 * scale)
    if name == "star":
        return star(n_spokes=400 * scale, n_hubs=3)
    if name == "paper":
        return paper_example(n=4 * scale, m=3 * scale)
    raise ValueError(f"unknown KB {name!r} (use lubm|chain|star|paper)")


def query_templates(name: str, scale: int):
    """(template, constant-pool) pairs; ``{c}`` is filled per request."""
    if name == "lubm":
        return [
            ('?s, ?c <- memberOf(?s, "{c}"), takesCourse(?s, ?c)',
             [f"dept{i}" for i in range(4 * scale)]),
            ('?s <- takesCourse(?s, "{c}"), GraduateStudent(?s)',
             [f"course{i}" for i in range(8 * scale)]),
            ('?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)',
             None),
            ('?x, ?u <- memberOf(?x, ?dv), subOrganizationOf(?dv, ?u)', None),
            ('?p <- teacherOf(?p, "{c}")', [f"course{i}" for i in range(8 * scale)]),
        ]
    if name == "chain":
        n = 60 * scale
        return [
            ('?y <- path("{c}", ?y)', [f"v{i:06d}" for i in range(n)]),
            ('?x <- path(?x, "{c}")', [f"v{i:06d}" for i in range(1, n + 1)]),
            ('?x, ?z <- edge(?x, ?y), edge(?y, ?z)', None),
        ]
    if name == "star":
        return [
            ('?y <- S("{c}", ?y)', [f"s{i:06d}" for i in range(0, 400 * scale, 2)]),
            ('?x, ?z <- S(?x, ?y), T(?y, ?z)', None),
        ]
    if name == "paper":
        return [
            ("?x, ?y <- S(?x, ?y)", None),
            ('?x, ?z <- P(?x, ?y), T(?y, ?z)', None),
            ('?y <- P("a2", ?y)', None),
        ]
    raise ValueError(name)


def make_stream(name: str, scale: int, n_queries: int, zipf: float, seed: int):
    rng = np.random.default_rng(seed)
    templates = query_templates(name, scale)
    out = []
    for _ in range(n_queries):
        template, pool = templates[int(rng.integers(0, len(templates)))]
        if pool is None:
            out.append(template)
            continue
        # Zipf-ish skew over the pool: popular constants dominate.
        # Fold the tail back with a modulo — clamping would pile every
        # out-of-range draw onto one element and degenerate the skew.
        rank = int(rng.zipf(zipf)) - 1 if zipf > 1.0 else int(
            rng.integers(0, len(pool))
        )
        out.append(template.format(c=pool[rank % len(pool)]))
    return out


def _rows_by_pred(items):
    out: dict[str, list] = {}
    for pred, row in items:
        out.setdefault(pred, []).append(row)
    return {p: np.asarray(r, dtype=np.int64) for p, r in out.items()}


def _parse_fact_spec(spec: str, dictionary):
    """``pred(t1, t2)`` -> ``(pred, (id1, id2))``; terms resolve through
    the KB dictionary, falling back to raw integer ids."""
    spec = spec.strip()
    if "(" not in spec or not spec.endswith(")"):
        raise ValueError(
            f"bad --explain spec {spec!r}; expected pred(term, term)"
        )
    pred, rest = spec.split("(", 1)
    terms = []
    for tok in rest[:-1].split(","):
        tok = tok.strip().strip("'\"")
        if dictionary is not None and tok in dictionary:
            terms.append(dictionary.id_of(tok))
        else:
            terms.append(int(tok))
    return pred.strip(), tuple(terms)


def _proof_summary(node: dict) -> dict:
    depth, n_nodes, all_verified = 0, 0, True
    stack = [(node, 1)]
    while stack:
        nd, d = stack.pop()
        n_nodes += 1
        depth = max(depth, d)
        all_verified = all_verified and bool(nd.get("verified"))
        for child in nd.get("children", ()):
            stack.append((child, d + 1))
    return {"depth": depth, "nodes": n_nodes, "verified": all_verified}


def _sample_derived(mat, explicit, n: int, seed: int):
    """Up to ``n`` (pred, terms) pairs drawn from the materialisation
    minus the explicit set — the facts a proof tree is non-trivial for."""
    from ..core.util import multicol_member

    pool = []
    for pred in sorted(mat):
        rows = np.asarray(mat[pred], dtype=np.int64)
        rows = rows.reshape(rows.shape[0], -1)
        exp = np.asarray(explicit.get(pred, np.zeros((0, 0))), dtype=np.int64)
        if exp.shape[0]:
            exp = exp.reshape(exp.shape[0], -1)
            if exp.shape[1] == rows.shape[1]:
                rows = rows[~multicol_member(rows, exp)]
        pool.extend((pred, tuple(int(v) for v in row)) for row in rows)
    if not pool:
        return []
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(pool), size=min(n, len(pool)), replace=False)
    return [pool[int(i)] for i in idx]


def make_update_batches(dataset, n_updates: int, size: int, seed: int):
    """Rotating explicit-fact update batches: each batch deletes ``size``
    facts from a shuffled pool and re-inserts the batch deleted one
    update earlier (the KB churns but never drains)."""
    rng = np.random.default_rng(seed + 1)
    pool = [
        (pred, tuple(int(v) for v in row))
        for pred, rows in dataset.items()
        for row in np.asarray(rows).reshape(len(rows), -1)
    ]
    rng.shuffle(pool)
    batches = []
    prev: list = []
    off = 0
    for _ in range(n_updates):
        cur = [pool[(off + j) % len(pool)] for j in range(size)]
        off += size
        # (deletions, additions)
        batches.append((_rows_by_pred(cur), _rows_by_pred(prev)))
        prev = cur
    return batches


def _serve_mvcc(args, report, inc, dictionary, stream, batches, ckpt,
                flush_telemetry, update_at):
    """Concurrent MVCC serving loop: ``--concurrency`` closed-loop
    client threads answer through the :class:`~repro.serving.ServingTier`
    (micro-batched admission over pinned epochs) while update batches
    flow through the tier's single writer thread every ``update_at``
    served queries."""
    from ..serving import ServingTier

    tier = ServingTier(
        inc,
        dictionary,
        result_cache_size=0 if args.no_result_cache else 1024,
        use_pallas=args.pallas,
        checkpoint=ckpt if args.live else None,
        checkpoint_every=args.checkpoint_every if args.live else 0,
        compact_threshold=args.compact_threshold if args.live else 0.0,
    )
    n_clients = max(args.concurrency, 1)
    lat_lock = threading.Lock()
    latencies: list[float] = []
    totals = {"answers": 0, "stale": 0, "served": 0}
    apply_lat: list[float] = []
    try:
        # warmup off the measured path: snapshots, plans, caches
        with span("serve.warmup"):
            for text in dict.fromkeys(stream[: min(50, len(stream))]):
                tier.answer(text)
        tier.reset_counters()
        tier.start()

        shards = [stream[i::n_clients] for i in range(n_clients)]

        def client(shard):
            local_lat = []
            answers = stale = 0
            for text in shard:
                t0 = time.perf_counter()
                resp = tier.answer(text)
                local_lat.append(time.perf_counter() - t0)
                answers += resp.n_answers
                stale += int(resp.stale)
                with lat_lock:
                    totals["served"] += 1
            with lat_lock:
                latencies.extend(local_lat)
                totals["answers"] += answers
                totals["stale"] += stale

        threads = [
            threading.Thread(target=client, args=(s,), daemon=True)
            for s in shards
            if s
        ]
        t_serve0 = time.perf_counter()
        for th in threads:
            th.start()
        # the main thread feeds the writer: one update batch per
        # `update_at` served queries, applied through tier.apply (the
        # single writer thread) and published as a fresh epoch
        next_batch = 0
        while any(th.is_alive() for th in threads):
            if (
                args.live
                and next_batch < len(batches)
                and totals["served"] >= (next_batch + 1) * update_at
            ):
                deletions, additions = batches[next_batch]
                next_batch += 1
                t0 = time.perf_counter()
                tier.apply_sync(additions=additions, deletions=deletions)
                apply_lat.append(time.perf_counter() - t0)
                sample_memory(phase="serve_batch", rss=False)
                flush_telemetry()
            else:
                time.sleep(0.001)
        for th in threads:
            th.join()
        t_serve = time.perf_counter() - t_serve0
    finally:
        tier.close()
    if args.live and ckpt is not None:
        ckpt.checkpoint(inc)  # final durable state via the LATEST pointer

    reg = get_registry()
    lat_arr = np.asarray(latencies) if latencies else np.zeros(1)
    lat_ms = lat_arr * 1e3
    lat_hist = reg.histogram("serve.query_s")
    for v in latencies:
        lat_hist.observe(float(v))
    publish_serving(tier)
    st = tier.stats()
    qps = len(latencies) / max(t_serve, 1e-9)
    report.emit(
        "serve",
        f"{len(latencies)} queries in {t_serve:.2f}s ({qps:.0f} q/s), "
        f"{totals['answers']} answers total",
        {"queries": len(latencies), "seconds": t_serve, "qps": qps,
         "answers": totals["answers"]},
    )
    report.emit(
        "latency",
        f"p50={np.percentile(lat_ms, 50):.3f}ms "
        f"p90={np.percentile(lat_ms, 90):.3f}ms "
        f"p99={np.percentile(lat_ms, 99):.3f}ms "
        f"max={lat_ms.max():.3f}ms",
        reg.snapshot("serve.query_s"),
    )
    report.emit(
        "serving",
        f"mvcc concurrency={n_clients}: {qps:.0f} q/s, "
        f"p99={np.percentile(lat_ms, 99):.3f}ms; "
        f"{st['batches']} micro-batches "
        f"(mean {st['mean_batch']:.1f}, max {st['max_batch']}, "
        f"{st['dedup_hits']} dedup / {st['grouped_queries']} grouped / "
        f"{st['cache_hits']} cached), "
        f"epochs: {st['epochs_published']} published, "
        f"{st['epochs_retired']} retired, {st['epochs_live']} live, "
        f"lag<={st['epoch_lag_max']}; {st['stale_reads']} stale reads, "
        f"{st['compactions']} compactions "
        f"({st['compactions_deferred']} deferred)",
        {
            "concurrency": n_clients,
            "qps": qps,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            **st,
        },
    )
    if st["stale_reads"]:
        report.emit(
            "serving-verify",
            f"FAILED: {st['stale_reads']} stale reads (must be 0)",
            {"stale_reads": st["stale_reads"]},
        )
        return 1
    report.emit(
        "store",
        f"{inc.store.n_nodes()} mu-nodes",
        {"mu_nodes": inc.store.n_nodes()},
    )
    if args.live:
        ap_ms = np.asarray(apply_lat) * 1e3 if apply_lat else np.zeros(1)
        inc_snap = reg.snapshot("inc.")
        report.emit(
            "live",
            f"{len(apply_lat)} update batches through the writer thread "
            f"(epoch {inc.epoch}), apply p50={np.percentile(ap_ms, 50):.2f}ms "
            f"p99={np.percentile(ap_ms, 99):.2f}ms; "
            f"{int(inc_snap.get('inc.n_deleted', 0))} deleted / "
            f"{int(inc_snap.get('inc.n_inserted', 0))} inserted facts",
            {**inc_snap, "apply_batches": len(apply_lat)},
        )
        if ckpt is not None:
            reg.gauge("storage.disk_bytes").set(ckpt.disk_nbytes())
            reg.gauge("storage.wal_bytes").set(ckpt.wal.nbytes())
            st_snap = reg.snapshot("storage.")
            report.emit(
                "storage",
                f"{int(st_snap.get('storage.checkpoints', 0))} checkpoints "
                f"under {args.checkpoint_dir} "
                f"({st_snap['storage.disk_bytes'] / 1024:.1f}KiB on disk)",
                st_snap,
            )
        if args.live_verify:
            from ..core import flat_seminaive

            want = {
                p: r
                for p, r in flat_seminaive(inc.program, inc.explicit).items()
                if r.shape[0]
            }
            got = inc.to_dict()
            ok = set(want) == set(got) and all(
                np.array_equal(want[p], got[p]) for p in want
            )
            report.emit(
                "live-verify",
                f"{'OK' if ok else 'MISMATCH'} "
                f"({sum(r.shape[0] for r in want.values())} facts)",
                {"ok": ok,
                 "facts": sum(r.shape[0] for r in want.values())},
            )
            if not ok:
                return 1
    return 0


def _main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kb", default="lubm", choices=["lubm", "chain", "star", "paper"])
    ap.add_argument("--scale", type=int, default=2)
    ap.add_argument("--n-queries", type=int, default=2000)
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-result-cache", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="route constant lookups through the Pallas kernel "
                         "(interpret mode off-TPU)")
    ap.add_argument("--live", action="store_true",
                    help="serve updates interleaved with queries through "
                         "the incremental maintenance subsystem")
    ap.add_argument("--mvcc", action="store_true",
                    help="serve through the epoch-based MVCC tier "
                         "(repro.serving): concurrent client threads, "
                         "micro-batched admission, single writer thread")
    ap.add_argument("--concurrency", type=int, default=1, metavar="N",
                    help="closed-loop client threads in --mvcc mode")
    ap.add_argument("--distributed", action="store_true",
                    help="shadow the KB on the sharded engine (semi-naive "
                         "delta exchange over all visible devices); with "
                         "--live, updates also ship through all_to_all and "
                         "the final state is differentially verified")
    ap.add_argument("--update-every", type=int, default=200,
                    help="apply an update batch every N queries (--live)")
    ap.add_argument("--update-size", type=int, default=8,
                    help="explicit facts deleted (and re-inserted) per batch")
    ap.add_argument("--live-verify", action="store_true",
                    help="differentially check the final store against a "
                         "from-scratch materialisation (--live)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="durable storage root: WAL + periodic snapshots")
    ap.add_argument("--checkpoint-every", type=int, default=5,
                    help="checkpoint every N applied update batches "
                         "(--live; a final checkpoint always runs)")
    ap.add_argument("--restore", action="store_true",
                    help="warm-start from the latest snapshot (+ WAL "
                         "replay in --live mode) instead of materialising")
    ap.add_argument("--compact-threshold", type=float, default=0.5,
                    help="dead mu-node fraction that triggers a "
                         "compaction epoch (--live; 0 disables)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome "
                         "trace-event / Perfetto JSON file here (in "
                         "--live mode, rewritten after every update "
                         "batch)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a flat JSON metrics-registry snapshot "
                         "here (periodic in --live mode, final always)")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="append one JSON object per report block here")
    ap.add_argument("--provenance", action="store_true",
                    help="record the derivation journal during "
                         "materialisation/updates (implied by --explain, "
                         "--explain-sample, --hot-rules)")
    ap.add_argument("--explain", action="append", default=[],
                    metavar="FACT",
                    help="explain one materialised fact, e.g. "
                         "'path(v000000, v000003)' — repeatable; terms "
                         "resolve through the KB dictionary (or raw ids)")
    ap.add_argument("--explain-sample", type=int, default=0, metavar="N",
                    help="explain N randomly sampled derived "
                         "(non-explicit) facts and verify their proofs")
    ap.add_argument("--hot-rules", action="store_true",
                    help="render the per-rule cost attribution table "
                         "(derived/redundant/time) from the journal")
    args = ap.parse_args(argv)
    if args.mvcc and args.distributed:
        ap.error("--mvcc and --distributed are mutually exclusive")

    want_prov = bool(
        args.provenance or args.explain or args.explain_sample
        or args.hot_rules
    )
    if want_prov:
        from ..obs.provenance import get_journal

        journal = get_journal()
        journal.enabled = True
        journal.clear()

    if args.trace_out:
        get_tracer().enable()
    report = ReportSink(args.report_json)

    def flush_telemetry() -> None:
        if args.metrics_out:
            write_metrics(args.metrics_out)
        if args.trace_out:
            write_chrome_trace(args.trace_out)

    program, dataset, dictionary = build_kb(args.kb, args.scale)
    n_explicit = sum(np.asarray(r).shape[0] for r in dataset.values())
    report.emit(
        f"kb:{args.kb}",
        f"{n_explicit} explicit facts, {len(program)} rules",
        {"explicit_facts": n_explicit, "rules": len(program),
         "scale": args.scale},
    )

    kb_label = f"{args.kb}:scale{args.scale}"
    ckpt = (
        CheckpointManager(args.checkpoint_dir, label=kb_label)
        if args.checkpoint_dir
        else None
    )
    static_snap = (
        os.path.join(args.checkpoint_dir, "frozen")
        if args.checkpoint_dir
        else None
    )

    t0 = time.perf_counter()
    inc = None
    recovery = None
    stats = None
    if args.live or args.mvcc:
        # --mvcc always serves from an IncrementalStore: the MVCC tier
        # publishes epochs by freezing it (static KBs just never apply)
        if ckpt is not None and args.restore and ckpt.has_snapshot():
            inc, recovery = ckpt.restore(program)
        else:
            inc = IncrementalStore(program)
            stats = inc.load(dataset)
            if ckpt is not None:
                # cold start owns the directory: stale snapshots/WAL from
                # a previous run must not interleave with fresh epochs
                ckpt.reset()
                inc.attach_wal(ckpt.wal)
        source = inc
    elif (
        args.restore
        and static_snap is not None
        and os.path.exists(os.path.join(static_snap, "manifest.json"))
    ):
        source = load_frozen(static_snap, expected_label=kb_label)
    else:
        eng = CMatEngine(program, dedup_index=True)
        eng.load(dataset)
        stats = eng.materialise()
        source = eng
        if static_snap is not None:
            frozen = eng.facts.freeze()
            rows = {p: frozen.snapshot(p) for p in frozen.predicates()}
            write_snapshot(
                static_snap, eng.facts, kind="frozen",
                label=kb_label, rows=rows,
            )
    t_mat = time.perf_counter() - t0
    if stats is not None:
        report.emit(
            "materialise",
            f"{stats.rounds} rounds over {stats.n_strata} strata, "
            f"{stats.n_facts} facts in {stats.n_meta_facts} meta-facts, "
            f"{t_mat:.2f}s",
            {"rounds": stats.rounds, "n_strata": stats.n_strata,
             "n_facts": stats.n_facts, "n_meta_facts": stats.n_meta_facts,
             "seconds": t_mat},
        )
        report.emit(
            "fixpoint",
            f"{stats.n_rule_applications} rule applications, "
            f"{stats.rule_applications_skipped} skipped without a probe; "
            f"plans: {stats.plan_cache.get('plans', 0)} compiled, "
            f"{stats.plan_cache.get('plan_hits', 0)} hits, "
            f"{stats.plan_cache.get('plan_replans', 0)} replans",
            {"n_rule_applications": stats.n_rule_applications,
             "rule_applications_skipped": stats.rule_applications_skipped,
             **{f"plan_cache.{k}": v for k, v in stats.plan_cache.items()}},
        )
    elif recovery is not None:
        # rendered from the registry scope the restore path published
        # into (the recovery object only contributes the snapshot name
        # and epochs — strings and levels the registry does not hold)
        snap = get_registry().snapshot("storage.")
        report.emit(
            "restore",
            f"warm start from {recovery.snapshot}: snapshot "
            f"{snap['storage.restore_snapshot_s']:.3f}s + "
            f"{int(snap['storage.wal_replayed'])} WAL "
            f"batches {snap['storage.restore_replay_s']:.3f}s (epoch "
            f"{recovery.snapshot_epoch} -> {recovery.final_epoch}), "
            f"{inc.facts.n_facts()} facts in "
            f"{inc.facts.n_meta_facts()} meta-facts; total {t_mat:.3f}s",
            {**snap, "snapshot": recovery.snapshot,
             "snapshot_epoch": recovery.snapshot_epoch,
             "final_epoch": recovery.final_epoch, "seconds": t_mat},
        )
    else:
        report.emit(
            "restore",
            f"frozen snapshot served from {static_snap}, {t_mat:.3f}s",
            {"snapshot": static_snap, "seconds": t_mat},
        )

    # high-water mark for the load/materialise/restore phase; the
    # per-predicate compression gauges start from the fresh store
    # (compaction epochs re-sample them as structure is re-shared)
    sample_memory(phase="restore" if stats is None else "materialise")
    facts_obj = inc.facts if inc is not None else getattr(source, "facts", None)
    if facts_obj is not None:
        publish_predicate_effectiveness(facts_obj)

    dist = None
    if args.distributed:
        import jax
        from jax.sharding import Mesh

        from ..core.distributed import DistributedEngine

        dprog = DistributedEngine.supported_program(program)
        dist_complete = len(dprog) == len(program)
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        # size the padded buffers from the host materialisation (2x
        # headroom over the biggest predicate): every device op scales
        # with capacity, not live rows, so oversizing taxes each round
        mat_rows = (
            inc.to_dict() if inc is not None
            else source.materialisation()
            if hasattr(source, "materialisation")
            else None
        )
        cap = 1 << 14
        if mat_rows:
            biggest = max(
                (np.asarray(r).shape[0] for r in mat_rows.values()),
                default=0,
            )
            cap = max(1 << 10, 1 << int(np.ceil(np.log2(max(2 * biggest, 2)))))
        dist = DistributedEngine(dprog, mesh, capacity=cap)
        t0 = time.perf_counter()
        # seed from the *restored* explicit set when the host store came
        # back from a checkpoint — the generator dataset no longer
        # reflects prior sessions' WAL batches and the final
        # differential check would flag a phantom mismatch
        dist.materialise(inc.explicit if inc is not None else dataset)
        ds = dist.stats
        report.emit(
            "distributed",
            f"{mesh.shape['data']} shard(s), {dist.rounds} "
            f"rounds over {ds.n_strata} strata in "
            f"{time.perf_counter() - t0:.2f}s; "
            f"{ds.n_rule_applications} rule applications "
            f"({ds.rule_applications_skipped} skipped), "
            f"{ds.rows_joined} rows joined, {ds.exchanges} exchanges "
            f"({ds.exchanges_skipped} elided by planner keys, "
            f"{ds.exchange_regrows} regrows)",
            get_registry().snapshot("dist."),
        )
        if not dist_complete:
            report.emit(
                "distributed",
                f"{len(program) - len(dprog)} rule(s) outside "
                f"the distributed fragment — differential checks disabled",
                {"unsupported_rules": len(program) - len(dprog)},
            )
        elif not args.live and hasattr(source, "materialisation"):
            reg = get_registry()
            try:
                dist.check_integrity(source.materialisation())
                reg.counter("dist.verify_ok").inc()
                report.emit(
                    "dist-verify",
                    "OK (sharded materialisation == host)",
                    reg.snapshot("dist.verify"),
                )
            except AssertionError as e:
                reg.counter("dist.verify_mismatch").inc()
                report.emit(
                    "dist-verify", f"MISMATCH: {e}",
                    {**reg.snapshot("dist.verify"), "error": str(e)},
                )
                return 1

    stream = make_stream(args.kb, args.scale, args.n_queries, args.zipf, args.seed)
    if not stream:
        print("[serve] empty query stream (--n-queries 0); nothing to do")
        return 0

    update_at = max(args.update_every, 1)
    batches = (
        make_update_batches(
            dataset, len(stream) // update_at + 1, args.update_size, args.seed
        )
        if args.live
        else []
    )

    if args.mvcc:
        rc = _serve_mvcc(
            args, report, inc, dictionary, stream, batches, ckpt,
            flush_telemetry, update_at,
        )
        if rc:
            return rc
        return _emit_tail(args, report, inc, inc, dictionary, flush_telemetry)

    qe = QueryEngine(
        source,
        dictionary,
        result_cache_size=0 if args.no_result_cache else 1024,
        use_pallas=args.pallas,
    )
    # warmup: build snapshots + plans off the measured path
    with span("serve.warmup"):
        for text in dict.fromkeys(stream[: min(50, len(stream))]):
            qe.answer(text)
    warm_cells = qe.frozen.snapshot_cells
    warm_cache = qe.cache_stats()

    latencies = np.zeros(len(stream))
    apply_lat: list[float] = []
    dist_lat: list[float] = []
    apply_tot: list = []  # per-batch stats (the journal is truncated
    n_answers = 0         # by checkpoints, so sums come from here)
    next_batch = 0
    n_checkpoints = 0
    compactions = []
    t_serve0 = time.perf_counter()
    for i, text in enumerate(stream):
        if args.live and i and i % update_at == 0 and next_batch < len(batches):
            with span("serve.update_batch", batch=next_batch):
                deletions, additions = batches[next_batch]
                next_batch += 1
                t0 = time.perf_counter()
                apply_tot.append(
                    inc.apply(additions=additions, deletions=deletions)
                )
                cs = inc.maybe_compact(args.compact_threshold)
                if cs is not None:
                    compactions.append(cs)
                qe.bump_epoch(inc)
                apply_lat.append(time.perf_counter() - t0)
                if dist is not None:
                    # the same batch ships through the all_to_all exchange
                    t0 = time.perf_counter()
                    dist.apply(additions=additions, deletions=deletions)
                    dist_lat.append(time.perf_counter() - t0)
                if (
                    ckpt is not None
                    and args.checkpoint_every > 0
                    and next_batch % args.checkpoint_every == 0
                ):
                    ckpt.checkpoint(inc)
                    n_checkpoints += 1
                sample_memory(phase="serve_batch", rss=False)
            # live telemetry: the trace/metrics files track the serving
            # loop batch by batch, not only at exit
            flush_telemetry()
        t0 = time.perf_counter()
        res = qe.answer(text)
        latencies[i] = time.perf_counter() - t0
        n_answers += res.n_answers
    t_serve = time.perf_counter() - t_serve0
    if args.live and ckpt is not None:
        ckpt.checkpoint(inc)  # final durable state for the next restore
        n_checkpoints += 1

    lat_ms = latencies * 1e3
    # measured-window counters only (warmup answered queries too)
    cache = {
        k: v - warm_cache[k] for k, v in qe.cache_stats().items()
    }
    hit_rate = cache["result_hits"] / max(
        cache["result_hits"] + cache["result_misses"], 1
    )
    # per-query latencies feed the registry histogram so the metrics
    # snapshot carries serving percentiles alongside the counters
    lat_hist = get_registry().histogram("serve.query_s")
    for v in latencies:
        lat_hist.observe(float(v))
    publish_query_cache(qe)
    report.emit(
        "serve",
        f"{len(stream)} queries in {t_serve:.2f}s "
        f"({len(stream) / max(t_serve, 1e-9):.0f} q/s), "
        f"{n_answers} answers total",
        {"queries": len(stream), "seconds": t_serve,
         "qps": len(stream) / max(t_serve, 1e-9), "answers": n_answers},
    )
    report.emit(
        "latency",
        f"p50={np.percentile(lat_ms, 50):.3f}ms "
        f"p90={np.percentile(lat_ms, 90):.3f}ms "
        f"p99={np.percentile(lat_ms, 99):.3f}ms "
        f"max={lat_ms.max():.3f}ms",
        get_registry().snapshot("serve.query_s"),
    )
    report.emit(
        "cache",
        f"result hit rate {hit_rate:.1%} "
        f"(plans: {cache['plan_hits']} hits / {cache['plan_misses']} misses); "
        f"snapshot warmup {warm_cells} cells, "
        f"{qe.frozen.snapshot_cells - warm_cells} after",
        {**get_registry().snapshot("query."), "hit_rate": hit_rate},
    )
    report.emit(
        "store",
        f"{qe.frozen.store.n_nodes()} mu-nodes (flat across stream)",
        {"mu_nodes": qe.frozen.store.n_nodes()},
    )
    if args.live:
        reg = get_registry()
        ap_ms = np.asarray(apply_lat) * 1e3 if apply_lat else np.zeros(1)
        # the registry's inc. scope accumulated these batch by batch via
        # publish_incremental; render the report line from its snapshot
        inc_snap = reg.snapshot("inc.")
        report.emit(
            "live",
            f"{len(apply_lat)} update batches applied "
            f"(epoch {inc.epoch}), apply p50={np.percentile(ap_ms, 50):.2f}ms "
            f"p99={np.percentile(ap_ms, 99):.2f}ms; "
            f"{int(inc_snap.get('inc.n_deleted', 0))} deleted / "
            f"{int(inc_snap.get('inc.n_inserted', 0))} inserted facts, "
            f"{int(inc_snap.get('inc.n_rederived', 0))} rederived; "
            f"{qe.stale_evictions} stale cache entries evicted",
            {**inc_snap, "stale_evictions": qe.stale_evictions},
        )
        usage = inc.mu_usage()
        reg.gauge("gc.nodes").set(usage.n_nodes)
        reg.gauge("gc.dead_fraction").set(usage.dead_fraction)
        reg.gauge("gc.resident_bytes").set(usage.total_bytes)
        gc_snap = reg.snapshot("gc.")
        n_compactions = int(gc_snap.get("gc.compactions", 0))
        compact_note = (
            f"{n_compactions} compaction epochs "
            f"(-{int(gc_snap.get('gc.nodes_reclaimed', 0))} "
            f"nodes, {int(gc_snap.get('gc.reshared_leaves', 0))} leaves "
            f"re-shared)"
            if n_compactions
            else "no compactions"
        )
        report.emit(
            "mu-gc",
            f"{usage.n_nodes} nodes "
            f"({usage.dead_fraction:.1%} dead, "
            f"{usage.total_bytes / 1024:.1f}KiB resident); {compact_note}",
            gc_snap,
        )
        if ckpt is not None:
            reg.gauge("storage.disk_bytes").set(ckpt.disk_nbytes())
            reg.gauge("storage.wal_bytes").set(ckpt.wal.nbytes())
            st_snap = reg.snapshot("storage.")
            report.emit(
                "storage",
                f"{int(st_snap.get('storage.checkpoints', 0))} checkpoints "
                f"under {args.checkpoint_dir} "
                f"({st_snap['storage.disk_bytes'] / 1024:.1f}KiB "
                f"on disk, WAL {int(st_snap['storage.wal_bytes'])}B), "
                f"journal {int(inc_snap.get('inc.journal_bytes', 0))}B "
                f"resident",
                st_snap,
            )
        if dist is not None and dist_lat:
            dl_ms = np.asarray(dist_lat) * 1e3
            ds = dist.stats
            report.emit(
                "distributed",
                f"{len(dist_lat)} update batches through the "
                f"exchange, apply p50={np.percentile(dl_ms, 50):.2f}ms "
                f"p99={np.percentile(dl_ms, 99):.2f}ms "
                f"(last batch: {ds.n_overdeleted} overdeleted, "
                f"{ds.n_rederived} rederived, {ds.n_inserted} inserted)",
                reg.snapshot("dist."),
            )
            if dist_complete:
                try:
                    dist.check_integrity(inc)
                    reg.counter("dist.verify_ok").inc()
                    report.emit(
                        "dist-verify",
                        "OK (sharded state == host store)",
                        reg.snapshot("dist.verify"),
                    )
                except AssertionError as e:
                    reg.counter("dist.verify_mismatch").inc()
                    report.emit(
                        "dist-verify", f"MISMATCH: {e}",
                        {**reg.snapshot("dist.verify"), "error": str(e)},
                    )
                    return 1
        if args.live_verify:
            from ..core import flat_seminaive

            want = {
                p: r
                for p, r in flat_seminaive(program, inc.explicit).items()
                if r.shape[0]
            }
            got = inc.to_dict()
            ok = set(want) == set(got) and all(
                np.array_equal(want[p], got[p]) for p in want
            )
            report.emit(
                "live-verify",
                f"{'OK' if ok else 'MISMATCH'} "
                f"({sum(r.shape[0] for r in want.values())} facts)",
                {"ok": ok,
                 "facts": sum(r.shape[0] for r in want.values())},
            )
            if not ok:
                return 1
    return _emit_tail(args, report, inc, source, dictionary, flush_telemetry)


def _emit_tail(args, report, inc, source, dictionary, flush_telemetry) -> int:
    """Shared trailing report blocks (provenance, kernels, memory,
    trace, metrics) for both the single-thread and MVCC serve paths."""
    want_prov = bool(
        args.provenance or args.explain or args.explain_sample
        or args.hot_rules
    )
    if want_prov:
        from ..obs.provenance import get_journal

        journal = get_journal()
        explain_src = (
            inc if inc is not None
            else source if hasattr(source, "explain_fact") else None
        )

        def _decode(tid):
            try:
                return dictionary.term_of(int(tid))
            except (KeyError, IndexError):  # id outside the dictionary
                return int(tid)

        targets = []
        parse_errors = []
        for spec in args.explain:
            try:
                targets.append(_parse_fact_spec(spec, dictionary))
            except ValueError as e:
                parse_errors.append(str(e))
        if args.explain_sample and explain_src is not None:
            mat = (
                inc.to_dict() if inc is not None
                else source.materialisation()
            )
            explicit = inc.explicit if inc is not None else source._explicit
            targets += _sample_derived(
                mat, explicit, args.explain_sample, args.seed
            )

        explanations = []
        if explain_src is not None:
            for pred, terms in targets:
                node = explain_src.explain_fact(pred, terms, decode=_decode)
                if node is None:
                    shown = ", ".join(str(_decode(t)) for t in terms)
                    explanations.append({
                        "fact": f"{pred}({shown})",
                        "found": False, "verified": False,
                    })
                else:
                    explanations.append({
                        "fact": node["fact"], "found": True,
                        **_proof_summary(node),
                    })
        hot = journal.hot_rules(10) if args.hot_rules else []
        n_ok = sum(1 for e in explanations if e["verified"])
        prov_bytes = journal.memory_report()["journal_bytes"]
        text = (
            f"journal {len(journal.records)} records "
            f"({journal.dropped} dropped, {prov_bytes / 1024:.1f}KiB)"
        )
        if explanations:
            text += f"; {n_ok}/{len(explanations)} explanations verified"
        elif targets and explain_src is None:
            text += "; explain skipped (frozen snapshot serving, no engine)"
        report.emit(
            "provenance", text,
            {"records": len(journal.records), "dropped": journal.dropped,
             "journal_bytes": prov_bytes, "explanations": explanations,
             "hot_rules": hot, "parse_errors": parse_errors,
             "explain_available": explain_src is not None},
        )
        for e in explanations:
            mark = "ok" if e["verified"] else (
                "NOT FOUND" if not e["found"] else "UNVERIFIED"
            )
            extra = (
                f" depth={e['depth']} nodes={e['nodes']}" if e["found"] else ""
            )
            print(f"  explain {e['fact']}: {mark}{extra}")
        if hot:
            print("  hot rules (by recorded time):")
            for h in hot:
                print(
                    f"    R{h['rule_id']:<3} {h['time_ns'] / 1e6:8.2f}ms  "
                    f"derived={h['derived']:<8} redundant={h['redundant']:<8} "
                    f"rounds={h['rounds_active']:<3} {h['rule']}"
                )
    if args.pallas:
        from ..kernels import ops

        traffic = ", ".join(
            f"{op}: {m['calls']} calls / {m['elements']} elems"
            for op, m in sorted(ops.meter().items())
        )
        report.emit(
            "kernels",
            traffic or "no kernel launches",
            get_registry().snapshot("kernels."),
        )
    # final roll-up: resident bytes from the reporter registry, RSS from
    # the kernel, and the peak watermarks the phase samples accumulated
    mem_rep = sample_memory()
    mem_snap = get_registry().snapshot("mem.")
    report.emit(
        "memory",
        f"resident {mem_rep['resident_bytes'] / 1024:.1f}KiB "
        f"(peak {int(mem_snap.get('mem.peak_resident_bytes', 0)) / 1024:.1f}"
        f"KiB), rss {mem_rep['rss_bytes'] / (1 << 20):.1f}MiB",
        mem_snap,
    )
    flush_telemetry()
    if args.trace_out:
        tr = get_tracer()
        report.emit(
            "trace",
            f"{len(tr.events)} span/instant events -> {args.trace_out} "
            f"({tr.dropped} dropped)",
            {"events": len(tr.events), "dropped": tr.dropped,
             "path": args.trace_out},
        )
    if args.metrics_out:
        report.emit(
            "metrics",
            f"{len(get_registry().snapshot())} metrics -> "
            f"{args.metrics_out}",
            {"path": args.metrics_out},
        )
    report.close()
    return 0


def main(argv=None):
    # --trace-out enables the process tracer and the provenance flags
    # enable the journal; restore both on every exit path so in-process
    # callers (tests, drivers) see no state leak
    from ..obs.provenance import get_journal

    tr = get_tracer()
    was_enabled = tr.enabled
    journal = get_journal()
    prov_was = journal.enabled
    try:
        return _main(argv)
    finally:
        if not was_enabled:
            tr.disable()
        if not prov_was:
            journal.enabled = False
            journal.clear()


if __name__ == "__main__":
    raise SystemExit(main())
