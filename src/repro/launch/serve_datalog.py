"""Batched datalog query serving: materialise once, answer a query stream.

    PYTHONPATH=src python -m repro.launch.serve_datalog --kb lubm \
        --n-queries 2000 --zipf 1.1

The request path the paper's preprocessing framing implies: load a KB,
run the compressed materialisation once, freeze the store, then serve a
stream of templated BGP queries through :class:`repro.query.QueryEngine`
(LRU plan + result caches, scratch-region reclamation per miss) and
report p50/p99 latency, throughput, cache hit rate, and the compressed
answering evidence (flat rows scanned vs stored rows per predicate).

Query streams are drawn from per-KB templates with Zipf-distributed
constants — a serving-realistic skew where popular entities repeat and
the result cache pays off.  ``--no-result-cache`` measures pure
evaluation throughput instead.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import CMatEngine
from ..core.generators import chain, lubm_like, paper_example, star
from ..query import QueryEngine


def build_kb(name: str, scale: int):
    if name == "lubm":
        return lubm_like(
            n_dept=4 * scale, n_students=100 * scale, n_courses=8 * scale, seed=0
        )
    if name == "chain":
        return chain(n=60 * scale)
    if name == "star":
        return star(n_spokes=400 * scale, n_hubs=3)
    if name == "paper":
        return paper_example(n=4 * scale, m=3 * scale)
    raise ValueError(f"unknown KB {name!r} (use lubm|chain|star|paper)")


def query_templates(name: str, scale: int):
    """(template, constant-pool) pairs; ``{c}`` is filled per request."""
    if name == "lubm":
        return [
            ('?s, ?c <- memberOf(?s, "{c}"), takesCourse(?s, ?c)',
             [f"dept{i}" for i in range(4 * scale)]),
            ('?s <- takesCourse(?s, "{c}"), GraduateStudent(?s)',
             [f"course{i}" for i in range(8 * scale)]),
            ('?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)',
             None),
            ('?x, ?u <- memberOf(?x, ?dv), subOrganizationOf(?dv, ?u)', None),
            ('?p <- teacherOf(?p, "{c}")', [f"course{i}" for i in range(8 * scale)]),
        ]
    if name == "chain":
        n = 60 * scale
        return [
            ('?y <- path("{c}", ?y)', [f"v{i:06d}" for i in range(n)]),
            ('?x <- path(?x, "{c}")', [f"v{i:06d}" for i in range(1, n + 1)]),
            ('?x, ?z <- edge(?x, ?y), edge(?y, ?z)', None),
        ]
    if name == "star":
        return [
            ('?y <- S("{c}", ?y)', [f"s{i:06d}" for i in range(0, 400 * scale, 2)]),
            ('?x, ?z <- S(?x, ?y), T(?y, ?z)', None),
        ]
    if name == "paper":
        return [
            ("?x, ?y <- S(?x, ?y)", None),
            ('?x, ?z <- P(?x, ?y), T(?y, ?z)', None),
            ('?y <- P("a2", ?y)', None),
        ]
    raise ValueError(name)


def make_stream(name: str, scale: int, n_queries: int, zipf: float, seed: int):
    rng = np.random.default_rng(seed)
    templates = query_templates(name, scale)
    out = []
    for _ in range(n_queries):
        template, pool = templates[int(rng.integers(0, len(templates)))]
        if pool is None:
            out.append(template)
            continue
        # Zipf-ish skew over the pool: popular constants dominate.
        # Fold the tail back with a modulo — clamping would pile every
        # out-of-range draw onto one element and degenerate the skew.
        rank = int(rng.zipf(zipf)) - 1 if zipf > 1.0 else int(
            rng.integers(0, len(pool))
        )
        out.append(template.format(c=pool[rank % len(pool)]))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kb", default="lubm", choices=["lubm", "chain", "star", "paper"])
    ap.add_argument("--scale", type=int, default=2)
    ap.add_argument("--n-queries", type=int, default=2000)
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-result-cache", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="route constant lookups through the Pallas kernel "
                         "(interpret mode off-TPU)")
    args = ap.parse_args(argv)

    program, dataset, dictionary = build_kb(args.kb, args.scale)
    n_explicit = sum(np.asarray(r).shape[0] for r in dataset.values())
    print(f"[kb:{args.kb}] {n_explicit} explicit facts, {len(program)} rules")

    eng = CMatEngine(program, dedup_index=True)
    eng.load(dataset)
    t0 = time.perf_counter()
    stats = eng.materialise()
    t_mat = time.perf_counter() - t0
    print(
        f"[materialise] {stats.rounds} rounds over {stats.n_strata} strata, "
        f"{stats.n_facts} facts in {stats.n_meta_facts} meta-facts, {t_mat:.2f}s"
    )
    print(
        f"[fixpoint] {stats.n_rule_applications} rule applications, "
        f"{stats.rule_applications_skipped} skipped without a probe; "
        f"plans: {stats.plan_cache.get('plans', 0)} compiled, "
        f"{stats.plan_cache.get('plan_hits', 0)} hits, "
        f"{stats.plan_cache.get('plan_replans', 0)} replans"
    )

    qe = QueryEngine(
        eng,
        dictionary,
        result_cache_size=0 if args.no_result_cache else 1024,
        use_pallas=args.pallas,
    )
    stream = make_stream(args.kb, args.scale, args.n_queries, args.zipf, args.seed)
    if not stream:
        print("[serve] empty query stream (--n-queries 0); nothing to do")
        return 0

    # warmup: build snapshots + plans off the measured path
    for text in dict.fromkeys(stream[: min(50, len(stream))]):
        qe.answer(text)
    warm_cells = qe.frozen.snapshot_cells
    warm_cache = qe.cache_stats()

    latencies = np.zeros(len(stream))
    n_answers = 0
    t_serve0 = time.perf_counter()
    for i, text in enumerate(stream):
        t0 = time.perf_counter()
        res = qe.answer(text)
        latencies[i] = time.perf_counter() - t0
        n_answers += res.n_answers
    t_serve = time.perf_counter() - t_serve0

    lat_ms = latencies * 1e3
    # measured-window counters only (warmup answered queries too)
    cache = {
        k: v - warm_cache[k] for k, v in qe.cache_stats().items()
    }
    hit_rate = cache["result_hits"] / max(
        cache["result_hits"] + cache["result_misses"], 1
    )
    print(
        f"[serve] {len(stream)} queries in {t_serve:.2f}s "
        f"({len(stream) / max(t_serve, 1e-9):.0f} q/s), "
        f"{n_answers} answers total"
    )
    print(
        f"[latency] p50={np.percentile(lat_ms, 50):.3f}ms "
        f"p90={np.percentile(lat_ms, 90):.3f}ms "
        f"p99={np.percentile(lat_ms, 99):.3f}ms "
        f"max={lat_ms.max():.3f}ms"
    )
    print(
        f"[cache] result hit rate {hit_rate:.1%} "
        f"(plans: {cache['plan_hits']} hits / {cache['plan_misses']} misses); "
        f"snapshot warmup {warm_cells} cells, "
        f"{qe.frozen.snapshot_cells - warm_cells} after"
    )
    print(f"[store] {qe.frozen.store.n_nodes()} mu-nodes (flat across stream)")
    if args.pallas:
        from ..kernels import ops

        traffic = ", ".join(
            f"{op}: {m['calls']} calls / {m['elements']} elems"
            for op, m in sorted(ops.meter().items())
        )
        print(f"[kernels] {traffic or 'no kernel launches'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
