"""Train / serve step builders.

``make_train_step`` returns the jittable ``(state, batch) -> (state,
metrics)`` with microbatch gradient accumulation (a ``lax.scan`` over
microbatches — compute/communication overlap falls out: the DP grad
all-reduce of microbatch i overlaps the forward of i+1 under XLA's
latency-hiding scheduler), optional int8 gradient compression on the DP
axes, and the ZeRO-sharded AdamW update.

``make_serve_step`` returns the decode step used by the inference shapes.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models import transformer
from ..optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compressed_grad_transform,
    init_error_feedback,
    warmup_cosine,
)

__all__ = ["TrainConfig", "init_train_state", "make_train_step",
           "make_serve_step", "make_prefill_step"]


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    grad_compression: bool = False
    warmup_steps: int = 200
    total_steps: int = 10_000


def init_train_state(key, cfg, train_cfg: TrainConfig):
    params = transformer.init_params(key, cfg)
    state = {
        "params": params,
        "opt": adamw_init(params),
    }
    if train_cfg.grad_compression:
        state["error_feedback"] = init_error_feedback(params)
    return state


def make_train_step(cfg, train_cfg: TrainConfig):
    """Build the train step for model config ``cfg``."""

    def loss_fn(params, batch):
        loss, metrics = transformer.forward_train(params, cfg, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        n_micro = train_cfg.microbatches
        if n_micro > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / n_micro,
                    g_acc, grads,
                )
                return (g_acc, l_acc + loss / n_micro), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro
            )
            metrics = {"xent": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        new_state = dict(state)
        if train_cfg.grad_compression:
            grads, new_err = compressed_grad_transform(
                grads, state["error_feedback"]
            )
            new_state["error_feedback"] = new_err

        lr_scale = warmup_cosine(
            state["opt"]["step"],
            warmup=train_cfg.warmup_steps,
            total=train_cfg.total_steps,
        )
        new_params, new_opt, opt_metrics = adamw_update(
            train_cfg.optimizer, params, grads, state["opt"], lr_scale
        )
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_serve_step(cfg):
    """Decode step: (params, token, cache, cache_len[, memory]) -> ..."""

    def serve_step(params, token, cache, cache_len, memory=None):
        return transformer.decode_step(
            params, cfg, token, cache, cache_len, memory=memory
        )

    return serve_step


def make_prefill_step(cfg):
    """Prefill: full forward returning last-position logits."""

    def prefill_step(params, batch):
        logits, _ = transformer.forward_logits(params, cfg, batch)
        return logits[:, -1]

    return prefill_step
