"""Fault tolerance: heartbeats, straggler mitigation, elastic re-meshing.

At 1000+ nodes the framework must assume per-step failure probability is
non-trivial.  Components (all host-side; hardware-agnostic, testable on
CPU):

* :class:`HeartbeatMonitor` — per-host liveness with a deadline; a missed
  deadline marks the host failed and triggers the restart path.
* :class:`StragglerMonitor` — robust step-time statistics (median + MAD);
  a host persistently above ``threshold x median`` is flagged so the
  launcher can migrate its shard (on TPU pods the usual cause is an ECC-
  throttled chip or a slow host NIC).
* :class:`ElasticPlan` — given surviving host count, picks the largest
  mesh that divides the global batch and reshards the checkpointed state
  (parameters are layout-free numpy trees; resharding = re-placement under
  the new mesh — tested by round-tripping through ``reshard_state``).
* :func:`run_with_recovery` — the supervision loop: step, checkpoint every
  N, on simulated/real failure restore latest checkpoint and continue —
  the integration test kills a step mid-run and asserts bit-exact
  continuation.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from .checkpoint import AsyncCheckpointer, latest_step, load_checkpoint

__all__ = [
    "HeartbeatMonitor",
    "StragglerMonitor",
    "ElasticPlan",
    "run_with_recovery",
]


class HeartbeatMonitor:
    def __init__(self, hosts: list[int], deadline_s: float = 60.0,
                 clock=time.monotonic):
        self.deadline = deadline_s
        self.clock = clock
        self.last_beat = {h: clock() for h in hosts}

    def beat(self, host: int) -> None:
        self.last_beat[host] = self.clock()

    def failed_hosts(self) -> list[int]:
        now = self.clock()
        return [h for h, t in self.last_beat.items()
                if now - t > self.deadline]


class StragglerMonitor:
    """Flags hosts whose step time is persistently above threshold x median."""

    def __init__(self, threshold: float = 1.5, window: int = 16,
                 min_flags: int = 8):
        self.threshold = threshold
        self.window = window
        self.min_flags = min_flags
        self.times: dict[int, list[float]] = {}
        self.flags: dict[int, int] = {}

    def record(self, host: int, step_time: float) -> None:
        self.times.setdefault(host, []).append(step_time)
        self.times[host] = self.times[host][-self.window :]

    def stragglers(self) -> list[int]:
        if len(self.times) < 2:
            return []
        recents = {h: np.median(t) for h, t in self.times.items() if t}
        med = float(np.median(list(recents.values())))
        out = []
        for h, t in recents.items():
            if t > self.threshold * med:
                self.flags[h] = self.flags.get(h, 0) + 1
                if self.flags[h] >= self.min_flags:
                    out.append(h)
            else:
                self.flags[h] = 0
        return out


@dataclass
class ElasticPlan:
    """Mesh downsizing plan after host loss."""

    total_hosts: int
    chips_per_host: int = 4
    model_parallel: int = 16
    candidates: list[int] = field(default_factory=list)

    def viable_meshes(self, surviving_hosts: int) -> list[tuple[int, int]]:
        """(data, model) meshes that fit on the surviving chips, largest
        first.  Model parallelism is kept fixed (weight layout survives);
        the data axis shrinks to the largest power-of-two that fits."""
        chips = surviving_hosts * self.chips_per_host
        data = chips // self.model_parallel
        if data < 1:
            return []  # not enough chips for even one model replica
        out = []
        p = 1
        while p * 2 <= data:
            p *= 2
        while p >= 1:
            out.append((p, self.model_parallel))
            p //= 2
        return out

    def pick(self, surviving_hosts: int) -> tuple[int, int]:
        meshes = self.viable_meshes(surviving_hosts)
        if not meshes:
            raise RuntimeError("not enough chips for model parallelism")
        return meshes[0]


def reshard_state(state, mesh, sharding_fn):
    """Re-place a host-side state tree onto a (new) mesh."""
    shardings = sharding_fn(state, mesh)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings
    )


def run_with_recovery(
    train_step,
    state,
    batches,
    *,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_failures: int = 10,
    fail_at: set[int] | None = None,
    start_step: int = 0,
):
    """Supervised training loop with checkpoint/restart.

    ``fail_at``: steps at which to inject a simulated failure (testing).
    Returns (final_state, last_step, n_recoveries).
    """
    ckpt = AsyncCheckpointer(ckpt_dir)
    failures = 0
    step = start_step
    restored = latest_step(ckpt_dir)
    if restored is not None:
        state, step = load_checkpoint(ckpt_dir, state)
        step += 1
    n = len(batches)
    while step < n:
        try:
            if fail_at and step in fail_at:
                fail_at.discard(step)
                raise RuntimeError(f"injected failure at step {step}")
            state, metrics = train_step(state, batches[step])
            if step % ckpt_every == 0:
                ckpt.wait()
                ckpt.save(step, state)
            step += 1
        except RuntimeError:
            failures += 1
            if failures > max_failures:
                raise
            ckpt.wait()
            restored = latest_step(ckpt_dir)
            if restored is None:
                step = start_step
            else:
                state, rstep = load_checkpoint(ckpt_dir, state)
                step = rstep + 1
    ckpt.wait()
    return state, step, failures
