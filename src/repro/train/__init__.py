"""Training substrate: steps, checkpointing, fault tolerance."""

from .checkpoint import (
    AsyncCheckpointer,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from .ft import ElasticPlan, HeartbeatMonitor, StragglerMonitor, run_with_recovery
from .train_step import (
    TrainConfig,
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "AsyncCheckpointer",
    "ElasticPlan",
    "HeartbeatMonitor",
    "StragglerMonitor",
    "TrainConfig",
    "init_train_state",
    "latest_step",
    "load_checkpoint",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
    "run_with_recovery",
    "save_checkpoint",
]
