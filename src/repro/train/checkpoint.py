"""Checkpointing: atomic, double-buffered, async — restart-safe.

No orbax in this environment; implemented on numpy + a manifest file.

* ``save`` writes to a temp dir then atomically renames (a crash mid-write
  can never corrupt the latest checkpoint);
* two checkpoint slots are retained (double buffering) so a failure during
  the newest save still leaves a loadable previous step;
* ``AsyncCheckpointer`` runs the host transfer + write on a worker thread —
  the train loop only blocks if a previous save is still in flight
  (same discipline as orbax async).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, state, keep: int = 2) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _flatten(state)
    tmp = os.path.join(directory, f".tmp-{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(
        os.path.join(tmp, "arrays.npz"),
        **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)},
    )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory) if d.startswith("step_")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_")
    )
    return steps[-1] if steps else None


def load_checkpoint(directory: str, state_like, step: int | None = None):
    """Restore into the structure of ``state_like`` (shapes must match)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(state_like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError("checkpoint/state structure mismatch")
    restored = [
        np.asarray(data[f"leaf_{i}"], dtype=np.asarray(l).dtype)
        for i, l in enumerate(leaves)
    ]
    return treedef.unflatten(restored), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer (double-buffered)."""

    def __init__(self, directory: str, keep: int = 2):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, state) -> None:
        self.wait()
        # device->host transfer happens here (blocking, cheap relative to
        # the write); the file I/O runs on the worker thread.
        host_state = jax.tree_util.tree_map(np.asarray, state)

        def work():
            save_checkpoint(self.directory, step, host_state, self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
