"""Checkpoint orchestration: snapshot directories + the WAL + GC.

Directory layout under one checkpoint root::

    ckpt/
      LATEST              name of the newest complete snapshot
      snap-00000004/      one snapshot per checkpoint epoch
      snap-00000019/
      wal.jsonl           update batches since the newest snapshot

Protocol (crash-safe at every step):

1. ``checkpoint(inc)`` writes ``snap-<epoch>.tmp`` fully (manifest
   last), renames it to ``snap-<epoch>``, then atomically rewrites
   ``LATEST`` — a crash anywhere leaves either the old or the new
   snapshot current, never a torn one.
2. Only then is the WAL truncated (records ``<= epoch`` are redundant)
   and the in-memory journal cleared; old snapshots beyond ``keep``
   are pruned.
3. ``restore(program)`` loads the snapshot named by ``LATEST``, replays
   newer WAL records through ``IncrementalStore.apply``, and only then
   attaches the WAL for subsequent logging.
"""

from __future__ import annotations

import os
import shutil
import time
from dataclasses import dataclass

from ..obs import get_registry, span
from ..obs.memory import register_reporter
from .format import (
    SnapshotError,
    _fsync_dir,
    read_manifest,
    restore_incremental,
    snapshot_nbytes,
    write_snapshot,
)
from .wal import WriteAheadLog

__all__ = ["CheckpointManager", "RecoveryStats"]

_LATEST = "LATEST"
_WAL = "wal.jsonl"


@dataclass
class RecoveryStats:
    snapshot: str
    snapshot_epoch: int
    final_epoch: int
    wal_batches: int
    wal_dropped: int
    t_snapshot_s: float
    t_replay_s: float
    verified: bool


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 2, label: str = ""):
        self.root = root
        self.keep = max(keep, 1)
        #: provenance tag stamped into manifests and checked on restore
        #: (a labelled manager refuses a differently-labelled snapshot)
        self.label = label
        os.makedirs(root, exist_ok=True)
        self.wal = WriteAheadLog(os.path.join(root, _WAL))
        #: MVCC pin/retire hooks: epochs pinned here (refcounted) or
        #: reported by the attached source keep their snapshot directory
        #: out of pruning and their WAL suffix out of truncation, so a
        #: reader holding an old epoch can always be recovered/audited
        self._pins: dict[int, int] = {}
        self._epoch_source = None
        register_reporter("storage", self)

    # ------------------------------------------------------------------ #
    # epoch pin/retire hooks (serving tier MVCC)
    # ------------------------------------------------------------------ #
    def attach_epoch_source(self, fn) -> None:
        """Register a zero-arg callable yielding the store epochs some
        reader currently pins (the serving tier passes its epoch
        registry's ``pinned_epochs``)."""
        self._epoch_source = fn

    def pin_epoch(self, epoch: int) -> None:
        """Refcounted manual pin: keep ``snap-<epoch>`` and the WAL
        records after it until :meth:`unpin_epoch`."""
        self._pins[epoch] = self._pins.get(epoch, 0) + 1

    def unpin_epoch(self, epoch: int) -> None:
        n = self._pins.get(epoch, 0) - 1
        if n <= 0:
            self._pins.pop(epoch, None)
        else:
            self._pins[epoch] = n

    def pinned_epochs(self) -> set[int]:
        pinned = set(self._pins)
        if self._epoch_source is not None:
            pinned.update(self._epoch_source())
        return pinned

    @staticmethod
    def _snap_epoch(name: str) -> int:
        try:
            return int(name.split("-", 1)[1])
        except (IndexError, ValueError):
            return -1

    def reset(self) -> None:
        """Wipe the checkpoint root: all snapshots, the LATEST pointer,
        and the WAL.  A *cold* (non-restore) run over a reused directory
        must call this before logging — otherwise its fresh epochs
        interleave with a previous run's WAL records and snapshots, and
        a later restore would stitch the two histories together."""
        for name in self.snapshots():
            shutil.rmtree(os.path.join(self.root, name))
        for name in os.listdir(self.root):
            path = os.path.join(self.root, name)
            if name.endswith(".tmp"):
                shutil.rmtree(path) if os.path.isdir(path) else os.remove(path)
        ptr = os.path.join(self.root, _LATEST)
        if os.path.exists(ptr):
            os.remove(ptr)
        self.wal.truncate()

    # ------------------------------------------------------------------ #
    def _snap_name(self, epoch: int) -> str:
        return f"snap-{epoch:08d}"

    def snapshots(self) -> list[str]:
        """Complete snapshot names, oldest first."""
        out = []
        for name in sorted(os.listdir(self.root)):
            path = os.path.join(self.root, name)
            if (
                name.startswith("snap-")
                and not name.endswith(".tmp")
                and os.path.isdir(path)
                and os.path.exists(os.path.join(path, "manifest.json"))
            ):
                out.append(name)
        return out

    def latest(self) -> str | None:
        """Path of the current snapshot (via LATEST, falling back to the
        newest complete directory if the pointer is missing)."""
        ptr = os.path.join(self.root, _LATEST)
        if os.path.exists(ptr):
            with open(ptr) as fh:
                name = fh.read().strip()
            path = os.path.join(self.root, name)
            if os.path.exists(os.path.join(path, "manifest.json")):
                return path
        snaps = self.snapshots()
        return os.path.join(self.root, snaps[-1]) if snaps else None

    def has_snapshot(self) -> bool:
        return self.latest() is not None

    # ------------------------------------------------------------------ #
    def checkpoint(self, inc) -> dict:
        """Write a snapshot of the incremental store's current epoch,
        publish it, and drop the now-redundant WAL/journal prefix."""
        with span("storage.checkpoint", epoch=inc.epoch) as sp:
            name = self._snap_name(inc.epoch)
            final = os.path.join(self.root, name)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            manifest = write_snapshot(
                tmp,
                inc.facts,
                kind="incremental",
                label=self.label,
                epoch=inc.epoch,
                round_tag=inc._round,
                rows=inc.rows.to_dict(),
                counts={p: c for p, c in inc.counts.items() if c.size},
                explicit={p: r for p, r in inc.explicit.items() if r.size},
                arities=inc.arities,
            )
            self._write_provenance(tmp)
            if os.path.exists(final):  # re-checkpoint, unchanged epoch
                shutil.rmtree(final)
            os.rename(tmp, final)
            ptr_tmp = os.path.join(self.root, _LATEST + ".tmp")
            with open(ptr_tmp, "w") as fh:
                fh.write(name + "\n")
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(ptr_tmp, os.path.join(self.root, _LATEST))
            _fsync_dir(self.root)
            # the snapshot is durable and published: WAL records and
            # journal entries at or below its epoch are redundant —
            # except the suffix after the oldest pinned epoch, which a
            # pinned reader's snapshot still needs to replay forward
            pinned = self.pinned_epochs()
            keep_after = min([inc.epoch, *pinned]) if pinned else inc.epoch
            self.wal.truncate(keep_after_epoch=keep_after)
            inc.truncate_journal()
            # never prune the snapshot LATEST points at, whatever its
            # name sorts as (a reused dir could hold higher-numbered
            # strangers), nor any snapshot whose epoch is pinned
            for old in self.snapshots()[: -self.keep]:
                if old != name and self._snap_epoch(old) not in pinned:
                    shutil.rmtree(os.path.join(self.root, old))
            sp.set(snapshot=name, pinned_epochs=len(pinned))
        reg = get_registry()
        reg.counter("storage.checkpoints").inc()
        reg.gauge("storage.checkpoint_epoch").set(inc.epoch)
        reg.gauge("storage.disk_bytes").set(self.disk_nbytes())
        return manifest

    def _write_provenance(self, snap_dir: str) -> None:
        """Sidecar the derivation journal into the snapshot directory
        (before the rename, so it is covered by the same atomicity).
        Written only when the journal is enabled — the sidecar is an
        optional extra, never part of the restore contract."""
        from ..obs.provenance import get_journal

        journal = get_journal()
        if not journal.enabled:
            return
        import json

        path = os.path.join(snap_dir, "provenance.json")
        with open(path, "w") as fh:
            json.dump(journal.to_payload(), fh)
            fh.flush()
            os.fsync(fh.fileno())

    def _load_provenance(self, snap_dir: str) -> bool:
        """Load a provenance sidecar into the live journal, if both the
        sidecar exists and the journal is currently enabled."""
        from ..obs.provenance import get_journal

        journal = get_journal()
        if not journal.enabled:
            return False
        path = os.path.join(snap_dir, "provenance.json")
        if not os.path.exists(path):
            return False
        import json

        with open(path) as fh:
            journal.load_payload(json.load(fh))
        return True

    # ------------------------------------------------------------------ #
    def restore(self, program, *, verify: bool = False, **store_kwargs):
        """Warm-start: latest snapshot + WAL replay.  Returns
        ``(inc, RecoveryStats)``; the WAL is attached afterwards so new
        batches keep logging to the same file."""
        snap = self.latest()
        if snap is None:
            raise SnapshotError(f"no snapshot under {self.root!r}")
        with span("storage.restore") as sp:
            t0 = time.perf_counter()
            inc, meta = restore_incremental(
                program, snap, verify=False,
                expected_label=self.label, **store_kwargs,
            )
            t_snap = time.perf_counter() - t0
            self._load_provenance(snap)
            t0 = time.perf_counter()
            n_replayed = self.wal.replay(inc, after_epoch=meta.epoch)
            t_replay = time.perf_counter() - t0
            if verify:
                inc.check_integrity()
            inc.attach_wal(self.wal)
            sp.set(
                snapshot_epoch=meta.epoch,
                final_epoch=inc.epoch,
                wal_batches=n_replayed,
            )
        reg = get_registry()
        reg.counter("storage.restores").inc()
        reg.counter("storage.wal_replayed").inc(n_replayed)
        reg.counter("storage.wal_dropped").inc(self.wal.n_dropped)
        reg.counter("storage.restore_snapshot_s").inc(t_snap)
        reg.counter("storage.restore_replay_s").inc(t_replay)
        return inc, RecoveryStats(
            snapshot=snap,
            snapshot_epoch=meta.epoch,
            final_epoch=inc.epoch,
            wal_batches=n_replayed,
            wal_dropped=self.wal.n_dropped,
            t_snapshot_s=t_snap,
            t_replay_s=t_replay,
            verified=verify,
        )

    # ------------------------------------------------------------------ #
    def latest_manifest(self) -> dict | None:
        snap = self.latest()
        return read_manifest(snap) if snap else None

    def disk_nbytes(self) -> int:
        """Bytes across all snapshots + the WAL."""
        total = self.wal.nbytes()
        for name in self.snapshots():
            total += snapshot_nbytes(os.path.join(self.root, name))
        return total

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter.  Everything here is on disk, so the
        ``_disk_bytes`` suffix keeps it out of the resident roll-up
        while still publishing under ``mem.storage.*``."""
        snaps = self.snapshots()
        snap_bytes = sum(
            snapshot_nbytes(os.path.join(self.root, name)) for name in snaps
        )
        return {
            "wal_disk_bytes": self.wal.nbytes(),
            "snapshots_disk_bytes": snap_bytes,
            "n_snapshots": len(snaps),
        }
