"""GC/compaction epochs for the mu-store.

Incremental deletion never rewrites shared structure in place: a
partially-hit meta-fact is replaced by a copy-mode split, leaving the
original columns (and the split's ``b_out`` halves) in the store with
nothing pointing at them.  Under sustained churn the dead fraction
climbs without bound — this module is the reclaim path ROADMAP calls
"mu-store compaction under churn".

:func:`mu_usage` measures it: nodes and resident bytes, total vs
reachable from the live meta-facts.  :func:`compact_store` rebuilds the
reachable DAG into a fresh node table and **hash-conses while doing
so** — leaves with identical RLE payloads collapse to one node even if
they were distinct in the source store (runs that only became identical
through earlier split epochs are re-shared), and identical Concat child
vectors collapse the same way.  The rebuild happens entirely off to the
side; only then is the live store redirected to the compacted state, in
a short reference-assignment section.  That lets the single-threaded
serving loop run compaction between requests with no pause beyond the
rebuild itself — but it is **not** safe against a concurrent reader: a
``MetaFact`` captured before the swap holds node ids from the old
table (background compaction off the serving thread is a ROADMAP
follow-on and would need a generation handle, not this swap).  What is
guaranteed: the fact set is identical before and after — row indexes,
count columns, and answers are untouched, and the compaction
differential tests pin ``to_dict()`` and query answers across the swap.
"""

from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass

from ..core.columns import ColumnStore
from ..core.metafacts import FactStore, MetaFact
from ..obs import get_registry, span
from ..obs.memory import publish_predicate_effectiveness

__all__ = ["MuUsage", "CompactionStats", "mu_usage", "compact_store"]


@dataclass
class MuUsage:
    n_nodes: int
    n_reachable: int
    total_bytes: int
    reachable_bytes: int

    @property
    def n_dead(self) -> int:
        return self.n_nodes - self.n_reachable

    @property
    def dead_fraction(self) -> float:
        return self.n_dead / self.n_nodes if self.n_nodes else 0.0

    @property
    def dead_bytes(self) -> int:
        return self.total_bytes - self.reachable_bytes


@dataclass
class CompactionStats:
    nodes_before: int
    nodes_after: int
    bytes_before: int
    bytes_after: int
    dead_fraction_before: float
    reshared_leaves: int  # distinct source leaves merged by hash-consing
    time_s: float


def mu_usage(facts: FactStore) -> MuUsage:
    """Dead-node accounting over the store backing ``facts``."""
    store = facts.store
    roots = [
        c
        for lst in (facts.all(p) for p in facts.predicates())
        for mf in lst
        for c in mf.columns
    ]
    reach = store.reachable(roots)
    reachable_bytes = sum(store.node_nbytes(c) for c in reach)
    return MuUsage(
        n_nodes=store.n_nodes(),
        n_reachable=len(reach),
        total_bytes=store.total_nbytes(),
        reachable_bytes=reachable_bytes,
    )


def _leaf_key(store: ColumnStore, cid: int) -> bytes:
    rv, rc = store.leaf_payload(cid)
    return hashlib.sha256(rv.tobytes() + b"\x00" + rc.tobytes()).digest()


def compact_store(inc) -> CompactionStats:
    """Rebuild the reachable mu-DAG of an incremental store and swap it
    in (between requests — see the module docstring for the exact
    concurrency contract).  The swapped-in state represents the
    identical fact set: rows, counts, and query answers are unchanged."""
    with span("storage.compact") as sp:
        stats = _compact_store(inc)
        sp.set(
            nodes_before=stats.nodes_before, nodes_after=stats.nodes_after
        )
    reg = get_registry()
    reg.counter("gc.compactions").inc()
    reg.counter("gc.nodes_reclaimed").inc(
        stats.nodes_before - stats.nodes_after
    )
    reg.counter("gc.bytes_reclaimed").inc(
        stats.bytes_before - stats.bytes_after
    )
    reg.counter("gc.reshared_leaves").inc(stats.reshared_leaves)
    reg.counter("gc.time_s").inc(stats.time_s)
    reg.gauge("gc.nodes").set(stats.nodes_after)
    reg.gauge("gc.bytes").set(stats.bytes_after)
    # compaction epochs re-share structure, so the per-predicate
    # compression-effectiveness gauges are re-sampled here (obs.memory:
    # the adaptive-hybrid-storage inputs track resharing, not staleness)
    publish_predicate_effectiveness(inc.facts, reg)
    return stats


def _compact_store(inc) -> CompactionStats:
    t0 = time.perf_counter()
    store: ColumnStore = inc.store
    facts: FactStore = inc.facts
    before = mu_usage(facts)

    fresh = ColumnStore()
    old_to_new: dict[int, int] = {}
    leaf_cons: dict[bytes, int] = {}
    concat_cons: dict[tuple[int, ...], int] = {}
    reshared = 0

    preds = list(facts.predicates())
    roots = [c for p in preds for mf in facts.all(p) for c in mf.columns]
    for cid in store.topo_order(roots):
        if store.is_leaf(cid):
            key = _leaf_key(store, cid)
            hit = leaf_cons.get(key)
            if hit is None:
                rv, rc = store.leaf_payload(cid)
                hit = fresh.new_leaf_rle(rv.copy(), rc.copy())
                leaf_cons[key] = hit
            else:
                reshared += 1
            old_to_new[cid] = hit
        else:
            kids = tuple(old_to_new[c] for c in store.children(cid))
            hit = concat_cons.get(kids)
            if hit is None:
                hit = fresh.new_concat(list(kids))
                concat_cons[kids] = hit
            old_to_new[cid] = hit

    new_facts: dict[str, list[MetaFact]] = {}
    for pred in preds:
        new_facts[pred] = [
            MetaFact(
                pred,
                tuple(old_to_new[c] for c in mf.columns),
                mf.length,
                mf.round,
            )
            for mf in facts.all(pred)
        ]

    # -- the swap (between requests; not concurrent-reader safe) ------- #
    store._nodes = fresh._nodes
    store._parents = fresh._parents
    store._unfold_cache = fresh._unfold_cache
    store._next_id = fresh._next_id
    store.recount_bytes()  # running byte counters track the new table
    facts._facts = new_facts
    inc.pre_mfs = {}
    inc.stats_view.refresh()

    after = mu_usage(facts)
    return CompactionStats(
        nodes_before=before.n_nodes,
        nodes_after=after.n_nodes,
        bytes_before=before.total_bytes,
        bytes_after=after.total_bytes,
        dead_fraction_before=before.dead_fraction,
        reshared_leaves=reshared,
        time_s=time.perf_counter() - t0,
    )
