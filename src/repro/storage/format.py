"""Snapshot format: the compressed store serialised with its sharing.

A snapshot is a directory holding a JSON manifest plus one columnar
blob::

    snap-00000012/
      manifest.json   format version, epoch, predicate table, TOC, checksum
      data.bin        zlib-compressed concatenation of all columns

The design constraint is the paper's: on-disk size must reflect the
*compressed* representation, not the unfolded one.  Three properties
deliver that:

* the ``_Leaf``/``_Concat`` DAG is written as a node table in
  topological order (children before parents), so shared subtrees are
  written once and references stay references;
* leaf **payloads** (RLE run arrays) are deduplicated by content hash —
  two distinct leaf nodes with identical runs share one payload record,
  which also re-shares runs that only became identical through later
  splits;
* all bulk data lives in flat int64 columns with offset vectors packed
  into one blob — the manifest's TOC maps names to (dtype, shape,
  offset), so a restore is one file read, one decompress, and
  zero-copy ``frombuffer`` slices (warm starts are on the serving path;
  a zip container's per-member bookkeeping was measurably slower than
  the actual fixpoint at small scales).

The blob's SHA-256 is recorded in the manifest and verified on load;
the manifest is written last, so a torn snapshot directory is detected
rather than half-loaded.

Node ids are *not* preserved across save/load — the loader rebuilds the
DAG bottom-up and remaps meta-fact columns — but the DAG shape, sharing,
lengths, and round tags are, which is everything the engines observe.

Alongside the mu-DAG and meta-facts, a snapshot carries the incremental
maintenance state: the :class:`RowIndex` rows, derivation-count columns
(positionally aligned with the rows), and the explicit fact set — so a
restored store resumes ``apply``/``freeze`` exactly where the saved one
stopped.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
import zlib
from dataclasses import dataclass, field

import numpy as np

from ..core.columns import ColumnStore
from ..core.frozen import FrozenFacts
from ..core.metafacts import FactStore, MetaFact

__all__ = [
    "FORMAT_VERSION",
    "SnapshotError",
    "SnapshotMeta",
    "check_label",
    "read_manifest",
    "snapshot_nbytes",
    "write_snapshot",
    "load_into",
    "load_frozen",
    "restore_incremental",
]

FORMAT_VERSION = 1

_MANIFEST = "manifest.json"
_DATA = "data.bin"
_SIDE_TABLES = ("rows", "counts", "explicit")

_EMPTY_I64 = np.zeros(0, dtype=np.int64)


class SnapshotError(RuntimeError):
    """Unreadable, corrupt, or version-incompatible snapshot."""


@dataclass
class SnapshotMeta:
    """What :func:`load_into` hands back besides the populated store."""

    epoch: int
    round: int
    kind: str
    rows: dict[str, np.ndarray] = field(default_factory=dict)
    counts: dict[str, np.ndarray] = field(default_factory=dict)
    explicit: dict[str, np.ndarray] = field(default_factory=dict)
    arities: dict[str, int] = field(default_factory=dict)
    manifest: dict = field(default_factory=dict)


# --------------------------------------------------------------------- #
# the blob container
# --------------------------------------------------------------------- #
def _write_blob(path: str, arrays: dict[str, np.ndarray]) -> dict:
    """Concatenate arrays into one zlib stream; returns the TOC."""
    entries: dict[str, dict] = {}
    parts: list[bytes] = []
    off = 0
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        buf = arr.tobytes()
        entries[name] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "offset": off,
        }
        parts.append(buf)
        off += len(buf)
    comp = zlib.compress(b"".join(parts), 1)
    with open(path, "wb") as fh:
        fh.write(comp)
        fh.flush()
        os.fsync(fh.fileno())
    return {
        "codec": "zlib",
        "raw_bytes": off,
        "sha256": hashlib.sha256(comp).hexdigest(),
        "entries": entries,
    }


def _read_blob(path: str, spec: dict, verify: bool) -> dict[str, np.ndarray]:
    """One read + one decompress + zero-copy slices (read-only arrays)."""
    with open(path, "rb") as fh:
        comp = fh.read()
    if verify:
        got = hashlib.sha256(comp).hexdigest()
        if got != spec["sha256"]:
            raise SnapshotError(f"checksum mismatch for {path!r}")
    raw = zlib.decompress(comp)
    if len(raw) != spec["raw_bytes"]:
        raise SnapshotError(f"size mismatch for {path!r}")
    out: dict[str, np.ndarray] = {}
    for name, e in spec["entries"].items():
        dtype = np.dtype(e["dtype"])
        count = int(np.prod(e["shape"], dtype=np.int64)) if e["shape"] else 1
        arr = np.frombuffer(
            raw, dtype=dtype, count=count, offset=int(e["offset"])
        )
        out[name] = arr.reshape(e["shape"])
    return out


# --------------------------------------------------------------------- #
# writing
# --------------------------------------------------------------------- #
def _export_mu(store: ColumnStore, roots: list[int]):
    """Node table + deduplicated payloads for the DAG under ``roots``.

    Returns ``(arrays, old_to_disk, stats)`` where ``old_to_disk`` maps
    live node ids to dense on-disk ids (topological order).
    """
    order = store.topo_order(roots)
    old_to_disk = {cid: i for i, cid in enumerate(order)}

    payload_index: dict[bytes, int] = {}
    pv_parts: list[np.ndarray] = []
    pc_parts: list[np.ndarray] = []
    payload_lens: list[int] = []
    kinds = np.zeros(len(order), dtype=np.uint8)  # 0 = leaf, 1 = concat
    payload_of = np.full(len(order), -1, dtype=np.int64)
    children_flat: list[int] = []
    children_len = np.zeros(len(order), dtype=np.int64)
    dup_bytes = 0

    for i, cid in enumerate(order):
        if store.is_leaf(cid):
            rv, rc = store.leaf_payload(cid)
            key = hashlib.sha256(
                rv.tobytes() + b"\x00" + rc.tobytes()
            ).digest()
            idx = payload_index.get(key)
            if idx is None:
                idx = len(payload_lens)
                payload_index[key] = idx
                pv_parts.append(rv)
                pc_parts.append(rc)
                payload_lens.append(int(rv.shape[0]))
            else:
                dup_bytes += int(rv.nbytes + rc.nbytes)
            payload_of[i] = idx
        else:
            kinds[i] = 1
            kids = store.children(cid)
            children_flat.extend(old_to_disk[c] for c in kids)
            children_len[i] = len(kids)

    payload_off = np.zeros(len(payload_lens) + 1, dtype=np.int64)
    if payload_lens:
        payload_off[1:] = np.cumsum(payload_lens)
    children_off = np.zeros(len(order) + 1, dtype=np.int64)
    if len(order):
        children_off[1:] = np.cumsum(children_len)

    arrays = {
        "mu/kinds": kinds,
        "mu/payload_of": payload_of,
        "mu/children_flat": np.asarray(children_flat, dtype=np.int64),
        "mu/children_off": children_off,
        "mu/pv_flat": (
            np.concatenate(pv_parts) if pv_parts else _EMPTY_I64
        ),
        "mu/pc_flat": (
            np.concatenate(pc_parts) if pc_parts else _EMPTY_I64
        ),
        "mu/payload_off": payload_off,
    }
    stats = {
        "n_nodes": len(order),
        "n_leaves": int((kinds == 0).sum()),
        "n_payloads": len(payload_lens),
        "payload_bytes": int(
            arrays["mu/pv_flat"].nbytes + arrays["mu/pc_flat"].nbytes
        ),
        "dedup_saved_bytes": dup_bytes,
    }
    return arrays, old_to_disk, stats


def write_snapshot(
    path: str,
    facts: FactStore,
    *,
    kind: str = "incremental",
    label: str = "",
    epoch: int = 0,
    round_tag: int = 0,
    rows: dict[str, np.ndarray] | None = None,
    counts: dict[str, np.ndarray] | None = None,
    explicit: dict[str, np.ndarray] | None = None,
    arities: dict[str, int] | None = None,
) -> dict:
    """Serialise a fact store (and optional maintenance state) to
    ``path``; returns the manifest dict.  The manifest is written last —
    a directory without one is not a snapshot."""
    os.makedirs(path, exist_ok=True)
    preds = sorted(p for p in facts.predicates() if facts.all(p))
    pred_idx = {p: i for i, p in enumerate(preds)}
    roots = [c for p in preds for mf in facts.all(p) for c in mf.columns]
    arrays, old_to_disk, mu_stats = _export_mu(facts.store, roots)

    mf_pred: list[int] = []
    mf_length: list[int] = []
    mf_round: list[int] = []
    cols_flat: list[int] = []
    cols_len: list[int] = []
    for p in preds:
        for mf in facts.all(p):
            mf_pred.append(pred_idx[p])
            mf_length.append(mf.length)
            mf_round.append(mf.round)
            cols_flat.extend(old_to_disk[c] for c in mf.columns)
            cols_len.append(mf.arity)
    cols_off = np.zeros(len(mf_pred) + 1, dtype=np.int64)
    if mf_pred:
        cols_off[1:] = np.cumsum(cols_len)
    arrays.update(
        {
            "facts/mf_pred": np.asarray(mf_pred, dtype=np.int64),
            "facts/mf_length": np.asarray(mf_length, dtype=np.int64),
            "facts/mf_round": np.asarray(mf_round, dtype=np.int64),
            "facts/cols_flat": np.asarray(cols_flat, dtype=np.int64),
            "facts/cols_off": cols_off,
        }
    )

    # maintenance state: three flat columns per table (pred index, shape,
    # concatenated data) — predicate names never appear as keys and the
    # TOC stays a handful of entries however many predicates exist
    side_preds = sorted(
        set(rows or ()) | set(counts or ()) | set(explicit or ())
    )
    side_idx = {p: i for i, p in enumerate(side_preds)}
    for table_name, table in (
        ("rows", rows),
        ("counts", counts),
        ("explicit", explicit),
    ):
        idxs: list[int] = []
        n0: list[int] = []
        n1: list[int] = []
        flats: list[np.ndarray] = []
        for p in sorted(table or {}, key=side_idx.__getitem__):
            arr = np.asarray(table[p], dtype=np.int64)
            if not arr.size:
                continue
            idxs.append(side_idx[p])
            n0.append(arr.shape[0])
            n1.append(arr.shape[1] if arr.ndim == 2 else 0)  # 0 = 1-D
            flats.append(arr.ravel())
        arrays[f"side/{table_name}_pred"] = np.asarray(idxs, dtype=np.int64)
        arrays[f"side/{table_name}_n0"] = np.asarray(n0, dtype=np.int64)
        arrays[f"side/{table_name}_n1"] = np.asarray(n1, dtype=np.int64)
        arrays[f"side/{table_name}_flat"] = (
            np.concatenate(flats) if flats else _EMPTY_I64
        )

    toc = _write_blob(os.path.join(path, _DATA), arrays)

    manifest = {
        "format": "compmat-snapshot",
        "version": FORMAT_VERSION,
        "kind": kind,
        # free-form provenance tag (e.g. "lubm:scale2"); loaders with an
        # expectation refuse a mismatch instead of serving the wrong KB
        "label": label,
        "created_unix": time.time(),
        "epoch": int(epoch),
        "round": int(round_tag),
        "predicates": [
            {
                "name": p,
                "arity": facts.all(p)[0].arity,
                "n_meta_facts": len(facts.all(p)),
                "n_facts": sum(mf.length for mf in facts.all(p)),
            }
            for p in preds
        ],
        "side_predicates": side_preds,
        "arities": dict(arities or {}),
        "store": mu_stats,
        "data": toc,
    }
    tmp = os.path.join(path, _MANIFEST + ".tmp")
    with open(tmp, "w") as fh:
        json.dump(manifest, fh, indent=1)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, os.path.join(path, _MANIFEST))
    _fsync_dir(path)
    return manifest


def _fsync_dir(path: str) -> None:
    """Make a rename within ``path`` durable (best effort — not every
    filesystem supports directory fsync)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic fs
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover
        pass
    finally:
        os.close(fd)


def snapshot_nbytes(path: str) -> int:
    """Total on-disk bytes of a snapshot directory."""
    return sum(
        os.path.getsize(os.path.join(path, f))
        for f in os.listdir(path)
        if os.path.isfile(os.path.join(path, f))
    )


# --------------------------------------------------------------------- #
# loading
# --------------------------------------------------------------------- #
def read_manifest(path: str) -> dict:
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        raise SnapshotError(f"no manifest in {path!r} (torn snapshot?)")
    with open(mpath) as fh:
        manifest = json.load(fh)
    if manifest.get("format") != "compmat-snapshot":
        raise SnapshotError(f"{path!r} is not a compmat snapshot")
    if manifest.get("version", 0) > FORMAT_VERSION:
        raise SnapshotError(
            f"snapshot version {manifest.get('version')} is newer than "
            f"this reader ({FORMAT_VERSION})"
        )
    return manifest


def load_into(
    path: str,
    store: ColumnStore,
    facts: FactStore,
    *,
    verify_checksums: bool = True,
) -> SnapshotMeta:
    """Rebuild a snapshot into the given (empty) store + fact store.

    The DAG is re-instantiated bottom-up, so sharing recorded on disk
    becomes sharing in memory; meta-fact columns are remapped to the
    fresh node ids."""
    manifest = read_manifest(path)
    z = _read_blob(
        os.path.join(path, _DATA), manifest["data"], verify_checksums
    )

    kinds = z["mu/kinds"]
    payload_of = z["mu/payload_of"]
    children_flat = z["mu/children_flat"]
    children_off = z["mu/children_off"]
    pv_flat, pc_flat, payload_off = (
        z["mu/pv_flat"], z["mu/pc_flat"], z["mu/payload_off"],
    )

    n_nodes = int(kinds.shape[0])
    disk_to_new = np.zeros(n_nodes, dtype=np.int64)
    payload_cache: dict[int, int] = {}  # payload idx -> first node id
    for i in range(n_nodes):
        if kinds[i] == 0:
            pidx = int(payload_of[i])
            hit = payload_cache.get(pidx)
            if hit is not None:
                # deduplicated payload: point this node at the one
                # already built (sharing is *gained* relative to save
                # time, never lost)
                disk_to_new[i] = hit
                continue
            lo, hi = int(payload_off[pidx]), int(payload_off[pidx + 1])
            nid = store.new_leaf_rle(pv_flat[lo:hi], pc_flat[lo:hi])
            payload_cache[pidx] = nid
            disk_to_new[i] = nid
        else:
            lo, hi = int(children_off[i]), int(children_off[i + 1])
            kids = [int(disk_to_new[c]) for c in children_flat[lo:hi]]
            disk_to_new[i] = store.new_concat(kids)

    preds = [p["name"] for p in manifest["predicates"]]
    mf_pred = z["facts/mf_pred"]
    mf_length = z["facts/mf_length"]
    mf_round = z["facts/mf_round"]
    cols_flat = z["facts/cols_flat"]
    cols_off = z["facts/cols_off"]
    for k in range(int(mf_pred.shape[0])):
        lo, hi = int(cols_off[k]), int(cols_off[k + 1])
        cols = tuple(int(disk_to_new[c]) for c in cols_flat[lo:hi])
        facts.add(
            MetaFact(
                preds[int(mf_pred[k])], cols,
                int(mf_length[k]), int(mf_round[k]),
            )
        )
    facts.current_round = int(manifest["round"])

    side_preds = manifest.get("side_predicates", [])
    meta = SnapshotMeta(
        epoch=int(manifest["epoch"]),
        round=int(manifest["round"]),
        kind=manifest["kind"],
        arities={k: int(v) for k, v in manifest.get("arities", {}).items()},
        manifest=manifest,
    )
    for label in _SIDE_TABLES:
        idxs = z[f"side/{label}_pred"]
        n0 = z[f"side/{label}_n0"]
        n1 = z[f"side/{label}_n1"]
        flat = z[f"side/{label}_flat"]
        off = 0
        table = getattr(meta, label)
        for k in range(int(idxs.shape[0])):
            rows_k, cols_k = int(n0[k]), int(n1[k])
            size = rows_k * max(cols_k, 1)
            arr = flat[off : off + size]
            off += size
            if cols_k:
                arr = arr.reshape(rows_k, cols_k)
            table[side_preds[int(idxs[k])]] = arr
    return meta


def check_label(manifest: dict, expected: str | None, path: str) -> None:
    """Refuse a snapshot written for a different KB than the caller
    expects (both sides must carry a label for the check to bind)."""
    got = manifest.get("label", "")
    if expected and got and got != expected:
        raise SnapshotError(
            f"snapshot at {path!r} is labelled {got!r}, expected "
            f"{expected!r} — refusing to serve the wrong KB"
        )


def load_frozen(
    path: str,
    *,
    verify_checksums: bool = True,
    expected_label: str | None = None,
) -> FrozenFacts:
    """Warm-start the read path: a :class:`FrozenFacts` whose sorted
    snapshots are seeded from the on-disk rows (no re-unfold)."""
    check_label(read_manifest(path), expected_label, path)
    store = ColumnStore()
    facts = FactStore(store)
    meta = load_into(path, store, facts, verify_checksums=verify_checksums)
    return FrozenFacts(facts, seed_rows=meta.rows or None)


def restore_incremental(
    program,
    path: str,
    *,
    verify: bool = False,
    verify_checksums: bool = True,
    expected_label: str | None = None,
    **store_kwargs,
):
    """Rebuild an :class:`~repro.incremental.IncrementalStore` from a
    snapshot directory — the warm-start path that replaces ``load()``.

    With ``verify=True`` the differential :meth:`check_integrity` gate
    runs after the rebuild (row index vs unfolded store, maintained
    derivation counts vs a recount)."""
    from ..incremental import IncrementalStore

    manifest = read_manifest(path)
    if manifest["kind"] != "incremental":
        raise SnapshotError(
            f"snapshot at {path!r} is kind {manifest['kind']!r}, "
            f"not 'incremental'"
        )
    check_label(manifest, expected_label, path)
    inc = IncrementalStore(program, **store_kwargs)
    meta = load_into(
        path, inc.store, inc.facts, verify_checksums=verify_checksums
    )
    for pred, rows in meta.rows.items():
        # written from RowIndex.to_dict(), so already sorted-unique
        inc.rows.seed_sorted(pred, rows)
    inc.explicit = {p: r for p, r in meta.explicit.items()}
    inc.arities.update(meta.arities)
    inc.epoch = meta.epoch
    inc._round = meta.round + 1
    if inc.counting:
        saved = set(meta.counts)
        missing = [
            p
            for p in inc._counting_preds
            if inc.rows.n_rows(p) and p not in saved
        ]
        if missing:
            # snapshot written without count columns (e.g. by a
            # counting=False store): rebuild them from scratch
            inc.counts = inc.recompute_counts()
        else:
            for p, arr in meta.counts.items():
                # blob slices are read-only; counts are scatter-updated
                inc.counts[p] = arr.copy()
    if verify:
        inc.check_integrity()
    return inc, meta
