"""Write-ahead log for incremental update batches.

One JSON line per :meth:`IncrementalStore.apply` call, written *before*
the store mutates::

    {"rec": {"epoch": 8, "adds": {...}, "dels": {...}}, "sha": "..."}

``sha`` is the SHA-256 of the canonical (sorted-keys) encoding of
``rec``, so torn or bit-rotted records are detected.  Recovery =
load the latest snapshot, then :meth:`replay` every record with
``epoch > snapshot.epoch`` through ``apply`` — the maintenance code is
the redo log's interpreter, no second mutation path exists.

A crash mid-write leaves a partial last line; :meth:`records` stops at
the first undecodable or checksum-failing record and reports how many
lines it dropped (the batch was never applied if its record is torn —
apply logs before mutating — so dropping the tail is exactly correct).

After a checkpoint at epoch ``e`` every record with ``epoch <= e`` is
redundant; :meth:`truncate` rewrites the log keeping only newer records
(normally none, leaving an empty file).
"""

from __future__ import annotations

import hashlib
import json
import os

import numpy as np

from ..obs import get_registry, span

__all__ = ["WriteAheadLog"]


def _canonical(rec: dict) -> str:
    return json.dumps(rec, sort_keys=True, separators=(",", ":"))


def _encode_batch(batch: dict[str, np.ndarray] | None) -> dict:
    return {
        pred: np.asarray(rows, dtype=np.int64).tolist()
        for pred, rows in (batch or {}).items()
        if np.asarray(rows).size
    }


def _decode_batch(batch: dict) -> dict[str, np.ndarray]:
    return {
        pred: np.asarray(rows, dtype=np.int64)
        for pred, rows in batch.items()
    }


class WriteAheadLog:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        #: torn/corrupt trailing lines dropped by the last :meth:`records`
        self.n_dropped = 0

    # ------------------------------------------------------------------ #
    def append(
        self,
        epoch: int,
        additions: dict[str, np.ndarray] | None,
        deletions: dict[str, np.ndarray] | None,
    ) -> None:
        with span("storage.wal.append", epoch=int(epoch)) as sp:
            rec = {
                "epoch": int(epoch),
                "adds": _encode_batch(additions),
                "dels": _encode_batch(deletions),
            }
            body = _canonical(rec)
            sha = hashlib.sha256(body.encode()).hexdigest()
            line = json.dumps({"rec": rec, "sha": sha}, sort_keys=True)
            with open(self.path, "a") as fh:
                fh.write(line + "\n")
                fh.flush()
                os.fsync(fh.fileno())
            sp.set(bytes=len(line) + 1)
        reg = get_registry()
        reg.counter("storage.wal.appends").inc()
        reg.counter("storage.wal.bytes").inc(len(line) + 1)

    # ------------------------------------------------------------------ #
    def records(self) -> list[dict]:
        """Verified records in log order; stops at the first torn or
        checksum-failing line (everything after it is unusable — later
        records depend on the dropped batch having been applied)."""
        if not os.path.exists(self.path):
            self.n_dropped = 0
            return []
        out: list[dict] = []
        dropped = 0
        with open(self.path) as fh:
            lines = fh.readlines()
        for i, line in enumerate(lines):
            line = line.strip()
            if not line:
                continue
            try:
                entry = json.loads(line)
                rec = entry["rec"]
                want = entry["sha"]
            except (json.JSONDecodeError, KeyError, TypeError):
                dropped = len(lines) - i
                break
            got = hashlib.sha256(_canonical(rec).encode()).hexdigest()
            if got != want:
                dropped = len(lines) - i
                break
            out.append(rec)
        self.n_dropped = dropped
        return out

    def replay(self, inc, after_epoch: int) -> int:
        """Re-apply every verified record newer than ``after_epoch``
        through ``inc.apply``; returns the number of batches replayed.

        The store must not have this WAL attached yet, or the replay
        would re-log itself — attach after recovery."""
        n = 0
        for rec in self.records():
            if rec["epoch"] <= after_epoch:
                continue
            inc.apply(
                additions=_decode_batch(rec["adds"]),
                deletions=_decode_batch(rec["dels"]),
            )
            n += 1
        return n

    # ------------------------------------------------------------------ #
    def truncate(self, keep_after_epoch: int | None = None) -> None:
        """Drop records with ``epoch <= keep_after_epoch`` (all of them
        when ``None``) — called after a checkpoint makes them redundant."""
        keep = (
            [
                rec
                for rec in self.records()
                if rec["epoch"] > keep_after_epoch
            ]
            if keep_after_epoch is not None
            else []
        )
        tmp = self.path + ".tmp"
        with open(tmp, "w") as fh:
            for rec in keep:
                body = _canonical(rec)
                sha = hashlib.sha256(body.encode()).hexdigest()
                fh.write(json.dumps({"rec": rec, "sha": sha}, sort_keys=True) + "\n")
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self.path)

    def nbytes(self) -> int:
        return os.path.getsize(self.path) if os.path.exists(self.path) else 0
