"""Durable storage for the compressed store (DESIGN.md §Storage).

The fourth layer under the engines: snapshots that serialise the
``<M, mu>`` representation with its structure sharing intact
(:mod:`.format`), a write-ahead log over incremental update batches
(:mod:`.wal`), checkpoint/restore orchestration (:mod:`.manager`), and
GC/compaction epochs that reclaim dead mu-nodes under churn
(:mod:`.compact`)::

    ckpt = CheckpointManager("ckpt/")
    inc.attach_wal(ckpt.wal)         # batches are logged before applied
    ...
    ckpt.checkpoint(inc)             # durable snapshot, WAL truncated
    ...
    inc, rec = ckpt.restore(program) # warm start: snapshot + WAL replay
"""

from .compact import CompactionStats, MuUsage, compact_store, mu_usage
from .format import (
    FORMAT_VERSION,
    SnapshotError,
    SnapshotMeta,
    load_frozen,
    load_into,
    read_manifest,
    restore_incremental,
    snapshot_nbytes,
    write_snapshot,
)
from .manager import CheckpointManager, RecoveryStats
from .wal import WriteAheadLog

__all__ = [
    "FORMAT_VERSION",
    "CheckpointManager",
    "CompactionStats",
    "MuUsage",
    "RecoveryStats",
    "SnapshotError",
    "SnapshotMeta",
    "WriteAheadLog",
    "compact_store",
    "load_frozen",
    "load_into",
    "mu_usage",
    "read_manifest",
    "restore_incremental",
    "snapshot_nbytes",
    "write_snapshot",
]
